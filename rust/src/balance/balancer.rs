//! The pluggable `Balancer` trait and its registry.
//!
//! §5.1 ships *multiple* post-balancing algorithms because no single
//! one fits every phase: the cost regime (linear vs quadratic
//! attention, packed vs padded batching) differs per encoder. Related
//! systems reach the same conclusion — modality-composition strategies
//! must be an extension point, not a match arm. This module turns the
//! old `Policy` enum dispatch into a trait + registry:
//!
//! * [`Balancer`] — one post-balancing algorithm: pure function from
//!   `(lens, d)` to an [`Assignment`], plus metadata (name, batching
//!   mode, cost regime) the orchestrator and CLI use to pick and
//!   describe it. `balance` threads a [`PlanScratch`] so repeated
//!   planning is allocation-free in the hot loops.
//! * [`registry`] — name → `Arc<dyn Balancer>` resolution for the
//!   `--balancer` CLI flag, the benches, and the property-test sweep.
//!   Every registered implementation is wrapped in [`Guarded`], which
//!   keeps the sampled (identity) arrangement whenever a heuristic
//!   regresses past it — the "adaptive to different scenarios"
//!   behaviour §5.1 requires, and the invariant the property tests
//!   pin: no registered balancer is ever worse than `NoBalance`.

use std::fmt;
use std::sync::Arc;

use super::cost::CostModel;
use super::incremental::{self, IncrementalPlan, PlanSource};
use super::scratch::PlanScratch;
use super::types::{identity_with_lens, Assignment, BatchingMode};

/// Which Eq.-2 cost form a balancer minimizes (paper §5.1, Appendix A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CostRegime {
    /// β ≪ α: cost is linear in batch length.
    Linear,
    /// β ≈ α: the attention quadratic matters (`α·L + β·Σ l²`).
    Quadratic,
    /// ConvTransformer encoders: padded attention dominated by
    /// `λ·b·max(l)²`.
    ConvAttention,
}

/// A post-balancing algorithm (paper §5.1 / Appendix A), pluggable into
/// any phase's dispatcher.
///
/// Implementations must be deterministic pure functions of `(lens, d)`:
/// every DP instance runs the same balancer on the all-gathered lengths
/// and must reach the same assignment without further communication
/// (§5.2.1).
pub trait Balancer: Send + Sync + fmt::Debug {
    /// Registry name (also the `--balancer` CLI spelling).
    fn name(&self) -> &'static str;

    /// How this algorithm expects the phase to batch sequences (Eq. 1).
    fn batching_mode(&self) -> BatchingMode;

    /// The cost regime the algorithm optimizes.
    fn cost_regime(&self) -> CostRegime;

    /// Produce `d` new mini-batches from the per-example lengths.
    /// `scratch` provides the reusable sort/heap/sum buffers; the
    /// returned assignment is the only allocation a warmed-up call
    /// makes.
    fn balance(
        &self,
        lens: &[usize],
        d: usize,
        scratch: &mut PlanScratch,
    ) -> Assignment;

    /// True for the `NoBalance` baseline: the dispatcher keeps every
    /// example on the instance that sampled it instead of re-dealing.
    fn is_identity(&self) -> bool {
        false
    }

    /// Plan incrementally from the previous step's assignment
    /// (ROADMAP's "incremental / cached rebalancing"): warm-start from
    /// `prev`'s rank→batch structure, run bounded local repair, and
    /// fall back to the from-scratch [`Balancer::balance`] when the
    /// batch diverged (different size, empty phase) or repair cannot
    /// certify the [`incremental::REPAIR_TOLERANCE`] band against a
    /// sound lower bound.
    ///
    /// Contract (pinned by `rust/tests/incremental_properties.rs`):
    ///
    /// * the output is a valid assignment of `lens` over `d` batches;
    /// * `makespan(incremental) <= makespan(from-scratch) ×
    ///   (1 + REPAIR_TOLERANCE)` under [`Balancer::cost_model`];
    /// * deterministic pure function of `(lens, d, prev)` (§5.2.1);
    /// * the warm path is never worse than the identity dealing (the
    ///   `NoBalance` floor) — diverging plans fall back cold.
    fn plan_incremental(
        &self,
        lens: &[usize],
        d: usize,
        prev: &Assignment,
        scratch: &mut PlanScratch,
    ) -> IncrementalPlan {
        self.plan_incremental_with(
            lens,
            d,
            prev,
            scratch,
            incremental::REPAIR_TOLERANCE,
        )
    }

    /// [`Balancer::plan_incremental`] with an explicit warm-acceptance
    /// tolerance (the `PlanOptions::tolerance` knob): the warm-started
    /// plan is kept only when its makespan certifies within
    /// `1 + tolerance` of the sound lower bound. Same contract as
    /// `plan_incremental`, with `tolerance` in place of
    /// [`incremental::REPAIR_TOLERANCE`].
    fn plan_incremental_with(
        &self,
        lens: &[usize],
        d: usize,
        prev: &Assignment,
        scratch: &mut PlanScratch,
        tolerance: f64,
    ) -> IncrementalPlan {
        if self.is_identity() {
            return IncrementalPlan {
                assignment: self.balance(lens, d, scratch),
                source: PlanSource::Cold,
                repair_moves: 0,
            };
        }
        let cm = self.cost_model();
        if let Some((assignment, repair_moves)) =
            incremental::warm_start_with(
                &cm, lens, d, prev, scratch, tolerance,
            )
        {
            // §5.1 floor holds on the warm path too: keep the warm plan
            // only while it beats (or ties) the identity dealing.
            if cm.makespan(&assignment)
                <= incremental::identity_makespan(&cm, lens, d) + 1e-9
            {
                return IncrementalPlan {
                    assignment,
                    source: PlanSource::Warm,
                    repair_moves,
                };
            }
        }
        IncrementalPlan {
            assignment: self.balance(lens, d, scratch),
            source: PlanSource::Cold,
            repair_moves: 0,
        }
    }

    /// The Eq.-2 cost function this balancer's output should be judged
    /// by (unit α; parametrized implementations override with their λ).
    fn cost_model(&self) -> CostModel {
        match (self.cost_regime(), self.batching_mode()) {
            (CostRegime::Linear, BatchingMode::Unpadded) => {
                CostModel::Linear { alpha: 1.0 }
            }
            (CostRegime::Linear, BatchingMode::Padded) => {
                CostModel::TransformerPadded { alpha: 1.0, beta: 0.0 }
            }
            (CostRegime::Quadratic, _) => {
                CostModel::TransformerUnpadded { alpha: 1.0, beta: 0.01 }
            }
            (CostRegime::ConvAttention, _) => {
                CostModel::ConvPadded { alpha: 1.0, lambda: 0.001 }
            }
        }
    }
}

/// The "w/o balance" baseline (§8.1): keep the sampled mini-batches.
/// When invoked directly (outside a dispatcher) it deals examples to
/// instances in sampled order, which is the sampled placement for
/// equal-sized source batches.
#[derive(Clone, Copy, Debug)]
pub struct NoBalance;

impl Balancer for NoBalance {
    fn name(&self) -> &'static str {
        "none"
    }

    fn batching_mode(&self) -> BatchingMode {
        BatchingMode::Unpadded
    }

    fn cost_regime(&self) -> CostRegime {
        CostRegime::Linear
    }

    fn balance(
        &self,
        lens: &[usize],
        d: usize,
        _scratch: &mut PlanScratch,
    ) -> Assignment {
        identity_with_lens(lens, d)
    }

    fn is_identity(&self) -> bool {
        true
    }
}

/// Wrapper giving every registered balancer the §5.1 safety net: if the
/// heuristic's makespan (under its own cost model) regresses past the
/// identity dealing, keep the identity. Guarantees the registry-wide
/// invariant `makespan(balanced) <= makespan(NoBalance)` that
/// `rust/tests/balancer_properties.rs` pins — on the from-scratch *and*
/// the incremental path (`rust/tests/incremental_properties.rs`).
#[derive(Debug)]
pub struct Guarded<B: Balancer>(pub B);

impl<B: Balancer> Balancer for Guarded<B> {
    fn name(&self) -> &'static str {
        self.0.name()
    }

    fn batching_mode(&self) -> BatchingMode {
        self.0.batching_mode()
    }

    fn cost_regime(&self) -> CostRegime {
        self.0.cost_regime()
    }

    fn is_identity(&self) -> bool {
        self.0.is_identity()
    }

    fn cost_model(&self) -> CostModel {
        self.0.cost_model()
    }

    fn balance(
        &self,
        lens: &[usize],
        d: usize,
        scratch: &mut PlanScratch,
    ) -> Assignment {
        let candidate = self.0.balance(lens, d, scratch);
        if self.0.is_identity() {
            return candidate;
        }
        // Score the identity dealing from chunk aggregates; the full
        // identity assignment is only materialized in the rare case it
        // actually wins, keeping the guard off the allocation-free hot
        // path.
        let cm = self.cost_model();
        if incremental::identity_makespan(&cm, lens, d)
            < cm.makespan(&candidate)
        {
            identity_with_lens(lens, d)
        } else {
            candidate
        }
    }

    fn plan_incremental_with(
        &self,
        lens: &[usize],
        d: usize,
        prev: &Assignment,
        scratch: &mut PlanScratch,
        tolerance: f64,
    ) -> IncrementalPlan {
        let mut plan = self
            .0
            .plan_incremental_with(lens, d, prev, scratch, tolerance);
        if self.0.is_identity() {
            return plan;
        }
        // Guard the incremental path too: whatever the inner warm/cold
        // logic produced, it must never lose to `NoBalance`.
        let cm = self.cost_model();
        if incremental::identity_makespan(&cm, lens, d)
            < cm.makespan(&plan.assignment)
        {
            plan.assignment = identity_with_lens(lens, d);
            plan.source = PlanSource::Cold;
            plan.repair_moves = 0;
        }
        plan
    }
}

/// Name → implementation resolution for CLI flags, benches, and tests.
pub mod registry {
    use super::*;
    use crate::balance::convpad::ConvPadBalancer;
    use crate::balance::greedy::GreedyLpt;
    use crate::balance::ilp::IlpBalancer;
    use crate::balance::kk::KarmarkarKarp;
    use crate::balance::padded::BinaryPadded;
    use crate::balance::prebalance::{BucketedPrebalance, FixedBatchPrebalance};
    use crate::balance::quadratic::QuadraticLpt;

    /// Every registered balancer name, in presentation order.
    pub const NAMES: &[&str] = &[
        "none",
        "greedy",
        "padded",
        "quadratic",
        "convpad",
        "kk",
        "ilp",
        "prebalance-fixed",
        "prebalance-bucketed",
    ];

    /// Resolve a registered balancer by name (aliases accepted).
    pub fn create(name: &str) -> Option<Arc<dyn Balancer>> {
        Some(match name {
            "none" | "no-balance" | "identity" => Arc::new(NoBalance),
            "greedy" | "lpt" | "alg1" => Arc::new(Guarded(GreedyLpt)),
            "padded" | "alg2" => Arc::new(Guarded(BinaryPadded)),
            "quadratic" | "alg3" => Arc::new(Guarded(QuadraticLpt {
                lambda: 0.01,
                tolerance: 32.0,
            })),
            // convpad self-guards: balance_convpad_with already returns
            // the best of {seeded, padded, identity} under its own
            // ConvPadded cost model, so the generic wrapper would only
            // re-score an identity that can never win.
            "convpad" | "alg4" => Arc::new(ConvPadBalancer { lambda: 0.001 }),
            "kk" | "karmarkar-karp" | "ldm" => {
                Arc::new(Guarded(KarmarkarKarp))
            }
            // ilp self-guards: its incumbent is seeded with the better
            // of LPT and the identity dealing, and branch-and-bound can
            // only improve on the seed.
            "ilp" | "exact" | "bnb" => Arc::new(IlpBalancer::default()),
            "prebalance-fixed" => Arc::new(Guarded(FixedBatchPrebalance)),
            "prebalance-bucketed" => Arc::new(Guarded(BucketedPrebalance)),
            _ => return None,
        })
    }

    /// Resolve or panic with the list of valid names — for internal
    /// callers whose names are compile-time constants.
    pub fn must(name: &str) -> Arc<dyn Balancer> {
        create(name).unwrap_or_else(|| {
            panic!("unknown balancer '{name}' (registered: {NAMES:?})")
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_every_listed_name() {
        for name in registry::NAMES {
            let b = registry::create(name)
                .unwrap_or_else(|| panic!("{name} missing from create()"));
            assert_eq!(b.name(), *name, "name() disagrees with registry key");
        }
        assert!(registry::create("nope").is_none());
    }

    #[test]
    fn aliases_resolve_to_the_same_algorithm() {
        assert_eq!(registry::must("lpt").name(), "greedy");
        assert_eq!(registry::must("karmarkar-karp").name(), "kk");
        assert_eq!(registry::must("no-balance").name(), "none");
        assert_eq!(registry::must("exact").name(), "ilp");
        assert_eq!(registry::must("bnb").name(), "ilp");
    }

    #[test]
    fn no_balance_is_identity() {
        let b = registry::must("none");
        assert!(b.is_identity());
        let mut s = PlanScratch::new();
        let a = b.balance(&[5, 6, 7, 8], 2, &mut s);
        assert_eq!(a[0].len(), 2);
        assert_eq!(a[0][0].len, 5);
        assert_eq!(a[1][1].len, 8);
    }

    #[test]
    fn guard_keeps_identity_when_heuristic_regresses() {
        /// A deliberately terrible balancer: everything in batch 0.
        #[derive(Debug)]
        struct AllInOne;
        impl Balancer for AllInOne {
            fn name(&self) -> &'static str {
                "all-in-one"
            }
            fn batching_mode(&self) -> BatchingMode {
                BatchingMode::Unpadded
            }
            fn cost_regime(&self) -> CostRegime {
                CostRegime::Linear
            }
            fn balance(
                &self,
                lens: &[usize],
                d: usize,
                _s: &mut PlanScratch,
            ) -> Assignment {
                let mut a: Assignment = vec![Vec::new(); d];
                for (id, &len) in lens.iter().enumerate() {
                    a[0].push(crate::balance::types::ExampleRef { id, len });
                }
                a
            }
        }
        let guarded = Guarded(AllInOne);
        let mut s = PlanScratch::new();
        let a = guarded.balance(&[4, 4, 4, 4], 2, &mut s);
        // The guard must fall back to the (balanced) identity dealing.
        assert_eq!(a[0].len(), 2);
        assert_eq!(a[1].len(), 2);
    }

    #[test]
    fn guard_clamps_a_bad_incremental_override() {
        /// From-scratch fine, but the incremental override is terrible:
        /// everything in batch 0, claimed warm.
        #[derive(Debug)]
        struct BadIncremental;
        impl Balancer for BadIncremental {
            fn name(&self) -> &'static str {
                "bad-incremental"
            }
            fn batching_mode(&self) -> BatchingMode {
                BatchingMode::Unpadded
            }
            fn cost_regime(&self) -> CostRegime {
                CostRegime::Linear
            }
            fn balance(
                &self,
                lens: &[usize],
                d: usize,
                _s: &mut PlanScratch,
            ) -> Assignment {
                identity_with_lens(lens, d)
            }
            fn plan_incremental_with(
                &self,
                lens: &[usize],
                d: usize,
                _prev: &Assignment,
                _s: &mut PlanScratch,
                _tolerance: f64,
            ) -> IncrementalPlan {
                let mut a: Assignment = vec![Vec::new(); d];
                for (id, &len) in lens.iter().enumerate() {
                    a[0].push(crate::balance::types::ExampleRef {
                        id,
                        len,
                    });
                }
                IncrementalPlan {
                    assignment: a,
                    source: PlanSource::Warm,
                    repair_moves: 0,
                }
            }
        }
        let guarded = Guarded(BadIncremental);
        let mut s = PlanScratch::new();
        let prev = guarded.balance(&[4, 4, 4, 4], 2, &mut s);
        let plan = guarded.plan_incremental(&[4, 4, 4, 4], 2, &prev, &mut s);
        // The incremental guard must clamp to the identity dealing.
        assert_eq!(plan.assignment[0].len(), 2);
        assert_eq!(plan.assignment[1].len(), 2);
        assert_eq!(plan.source, PlanSource::Cold);
    }

    #[test]
    fn no_balance_incremental_stays_identity() {
        let b = registry::must("none");
        let mut s = PlanScratch::new();
        let prev = b.balance(&[5, 6, 7, 8], 2, &mut s);
        let plan = b.plan_incremental(&[5, 6, 7, 8], 2, &prev, &mut s);
        assert_eq!(plan.assignment, prev);
        assert_eq!(plan.source, PlanSource::Cold);
    }
}
