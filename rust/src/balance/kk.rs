//! Karmarkar–Karp largest-differencing (LDM) post-balancing, with LPT
//! fallback — the registry's proof-of-pluggability algorithm.
//!
//! LPT (Algorithm 1) commits each sequence to the currently-lightest
//! batch and can paint itself into a corner on heavy-tailed length
//! distributions: a late long sequence lands on a batch that already
//! carries medium ones. The largest-differencing method instead keeps a
//! priority queue of *partial d-way partitions* ordered by their spread
//! (max − min batch sum) and repeatedly merges the two most-spread
//! partitions, pairing the largest batch of one with the smallest of
//! the other. Differencing cancels imbalance instead of accumulating
//! it; on the log-normal batches §2.3 describes it typically tightens
//! the makespan over LPT by a few percent, which at cluster scale is a
//! few percent of straggler time on every step.
//!
//! Cost is O(n·d·log) versus LPT's O(n log n), so the solver falls back
//! to plain LPT when `n·d` grows past a budget (the result is never
//! worse than LPT either way: the best of both is returned).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::balancer::{Balancer, CostRegime};
use super::greedy::balance_lpt_with;
use super::scratch::PlanScratch;
use super::types::{
    batch_length, Assignment, BatchingMode, ExampleRef,
};

/// Merge work is O(n·d); past this product the differencing gain no
/// longer pays for itself against the prefetch-overlap budget and
/// [`balance_kk_with`] returns plain LPT. Public so benches and docs
/// can tell which path a given workload exercises.
pub const KK_MAX_WORK: usize = 1 << 20;

/// One partial d-way partition: batches sorted by descending sum.
struct Partial {
    /// `(sum, members)` per batch, descending by sum.
    parts: Vec<(usize, Vec<ExampleRef>)>,
    /// max − min batch sum: the differencing key.
    spread: usize,
    /// Creation sequence number: deterministic tie-break.
    seq: usize,
}

impl PartialEq for Partial {
    fn eq(&self, other: &Self) -> bool {
        self.spread == other.spread && self.seq == other.seq
    }
}

impl Eq for Partial {}

impl PartialOrd for Partial {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Partial {
    fn cmp(&self, other: &Self) -> Ordering {
        // Max-heap on spread; among equal spreads pop the older partial
        // first (smaller seq compares greater).
        self.spread
            .cmp(&other.spread)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

fn unpadded_makespan(a: &Assignment) -> usize {
    a.iter()
        .map(|b| batch_length(b, BatchingMode::Unpadded))
        .max()
        .unwrap_or(0)
}

/// Karmarkar–Karp d-way partitioning; returns the better of LDM and LPT
/// under the unpadded makespan.
pub fn balance_kk_with(
    lens: &[usize],
    d: usize,
    scratch: &mut PlanScratch,
) -> Assignment {
    assert!(d > 0, "need at least one DP instance");
    let n = lens.len();
    let lpt = balance_lpt_with(lens, d, scratch);
    if d < 2 || n == 0 || n.saturating_mul(d) > KK_MAX_WORK {
        return lpt;
    }

    let mut heap: BinaryHeap<Partial> = BinaryHeap::with_capacity(n);
    for (id, &len) in lens.iter().enumerate() {
        let mut parts = Vec::with_capacity(d);
        parts.push((len, vec![ExampleRef { id, len }]));
        parts.extend((1..d).map(|_| (0, Vec::new())));
        heap.push(Partial { parts, spread: len, seq: id });
    }

    let mut seq = n;
    while heap.len() > 1 {
        let a = heap.pop().expect("heap len > 1");
        let b = heap.pop().expect("heap len > 1");
        // Differencing: pair a's largest batch with b's smallest.
        let mut parts: Vec<(usize, Vec<ExampleRef>)> = a
            .parts
            .into_iter()
            .zip(b.parts.into_iter().rev())
            .map(|((sa, mut ma), (sb, mb))| {
                ma.extend(mb);
                (sa + sb, ma)
            })
            .collect();
        // Re-sort descending by sum; ties by first member id so the
        // merge order (and thus the output) is fully deterministic.
        parts.sort_unstable_by(|x, y| {
            let kx = x.1.first().map(|e| e.id).unwrap_or(usize::MAX);
            let ky = y.1.first().map(|e| e.id).unwrap_or(usize::MAX);
            y.0.cmp(&x.0).then(kx.cmp(&ky))
        });
        let spread = parts[0].0 - parts[d - 1].0;
        heap.push(Partial { parts, spread, seq });
        seq += 1;
    }

    let kk: Assignment = heap
        .pop()
        .expect("one partial remains")
        .parts
        .into_iter()
        .map(|(_, members)| members)
        .collect();

    // LPT fallback: never ship a differencing result that regressed.
    if unpadded_makespan(&kk) <= unpadded_makespan(&lpt) {
        kk
    } else {
        lpt
    }
}

/// Convenience wrapper over a fresh scratch.
pub fn balance_kk(lens: &[usize], d: usize) -> Assignment {
    balance_kk_with(lens, d, &mut PlanScratch::new())
}

/// Registry entry: `kk` (aliases `karmarkar-karp`, `ldm`).
#[derive(Clone, Copy, Debug)]
pub struct KarmarkarKarp;

impl Balancer for KarmarkarKarp {
    fn name(&self) -> &'static str {
        "kk"
    }

    fn batching_mode(&self) -> BatchingMode {
        BatchingMode::Unpadded
    }

    fn cost_regime(&self) -> CostRegime {
        CostRegime::Linear
    }

    fn balance(
        &self,
        lens: &[usize],
        d: usize,
        scratch: &mut PlanScratch,
    ) -> Assignment {
        balance_kk_with(lens, d, scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balance::greedy::balance_lpt;
    use crate::balance::types::{
        assert_valid_assignment, identity_with_lens, makespan,
    };
    use crate::util::prop::check;

    #[test]
    fn beats_lpt_on_the_classic_instance() {
        // lens 8,7,6,5,4 over 2 instances: LPT gives 17 ({8,5,4} vs
        // {7,6}); differencing reaches 16 (optimum is 15).
        let lpt = makespan(&balance_lpt(&[8, 7, 6, 5, 4], 2), BatchingMode::Unpadded);
        let kk = makespan(&balance_kk(&[8, 7, 6, 5, 4], 2), BatchingMode::Unpadded);
        assert_eq!(lpt, 17);
        assert!(kk < lpt, "kk {kk} !< lpt {lpt}");
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        let a = balance_kk(&[], 4);
        assert_eq!(a.len(), 4);
        assert!(a.iter().all(|b| b.is_empty()));
        let a = balance_kk(&[10], 3);
        assert_valid_assignment(&a, 1, 3);
        let a = balance_kk(&[3, 3], 1);
        assert_valid_assignment(&a, 2, 1);
    }

    #[test]
    fn deterministic() {
        let lens = vec![9, 9, 8, 7, 7, 3, 2, 2, 1, 14, 5, 5];
        assert_eq!(balance_kk(&lens, 3), balance_kk(&lens, 3));
    }

    #[test]
    fn prop_valid_and_never_worse_than_lpt() {
        check("kk <= lpt", 150, |g| {
            let d = g.usize(1, 10);
            let n = g.usize(0, 120);
            let lens = g.seq_lengths(n, 3.2, 1.2);
            let kk = balance_kk(&lens, d);
            assert_valid_assignment(&kk, n, d);
            let m_kk = makespan(&kk, BatchingMode::Unpadded);
            let m_lpt =
                makespan(&balance_lpt(&lens, d), BatchingMode::Unpadded);
            assert!(m_kk <= m_lpt, "kk {m_kk} > lpt {m_lpt}");
        });
    }

    #[test]
    fn prop_never_worse_than_identity() {
        check("kk <= identity", 100, |g| {
            let d = g.usize(2, 8);
            let n = g.usize(d, d * 16);
            let lens = g.seq_lengths(n, 3.5, 1.0);
            let m_kk =
                makespan(&balance_kk(&lens, d), BatchingMode::Unpadded);
            let m_id = makespan(
                &identity_with_lens(&lens, d),
                BatchingMode::Unpadded,
            );
            assert!(m_kk <= m_id, "kk {m_kk} > identity {m_id}");
        });
    }

    #[test]
    fn improves_makespan_on_heavy_tails_in_aggregate() {
        // Across many heavy-tailed draws, differencing must strictly
        // beat LPT a meaningful fraction of the time (it ties on easy
        // instances) and never lose.
        let mut wins = 0;
        let mut rounds = 0;
        check("kk wins sometimes", 60, |g| {
            let d = g.usize(3, 8);
            let lens = g.seq_lengths(d * 12, 4.5, 1.6);
            let m_kk =
                makespan(&balance_kk(&lens, d), BatchingMode::Unpadded);
            let m_lpt =
                makespan(&balance_lpt(&lens, d), BatchingMode::Unpadded);
            rounds += 1;
            if m_kk < m_lpt {
                wins += 1;
            }
        });
        assert!(
            wins * 10 >= rounds,
            "kk strictly improved only {wins}/{rounds} heavy-tailed draws"
        );
    }

    #[test]
    fn falls_back_to_lpt_above_the_work_budget() {
        // n*d beyond the budget must still return a valid (LPT) answer.
        let mut g = crate::util::prop::Gen::new(9);
        let lens = g.seq_lengths(3000, 4.0, 1.0);
        let a = balance_kk(&lens, 512);
        assert_valid_assignment(&a, 3000, 512);
        assert_eq!(a, balance_lpt(&lens, 512));
    }
}
