//! Core types for batch post-balancing.

/// A reference to one example's sequence in one phase: its global index
/// (stable across rearrangements) and its sequence length in this phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExampleRef {
    /// Global example id: enumeration order of (source instance, slot).
    pub id: usize,
    /// Sequence length of this example in the current phase.
    pub len: usize,
}

/// How a phase batches its sequences (paper Eq. 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchingMode {
    /// Sequences padded to the max length: `L = b * max(l)`.
    Padded,
    /// Packed without padding: `L = sum(l)`.
    Unpadded,
}

/// The output of a balancing algorithm: `assignment[i]` is the new
/// mini-batch for DP instance `i`.
pub type Assignment = Vec<Vec<ExampleRef>>;

/// Batch length per Eq. (1).
pub fn batch_length(batch: &[ExampleRef], mode: BatchingMode) -> usize {
    match mode {
        BatchingMode::Padded => {
            let max = batch.iter().map(|e| e.len).max().unwrap_or(0);
            batch.len() * max
        }
        BatchingMode::Unpadded => batch.iter().map(|e| e.len).sum(),
    }
}

/// The minimax objective value of an assignment under Eq. (1) lengths.
pub fn makespan(assignment: &Assignment, mode: BatchingMode) -> usize {
    assignment
        .iter()
        .map(|b| batch_length(b, mode))
        .max()
        .unwrap_or(0)
}

/// The identity assignment: examples dealt to instances in their sampled
/// order (round-robin over equally-sized source mini-batches).
pub fn identity_assignment(n: usize, d: usize) -> Assignment {
    let mut a: Assignment = vec![Vec::new(); d];
    // Examples are enumerated source-major: instance i contributed the
    // contiguous block [i*n/d, (i+1)*n/d) when batches are equal-sized;
    // for the general case deal contiguous chunks as evenly as possible.
    let base = n / d;
    let extra = n % d;
    let mut g = 0;
    for (i, batch) in a.iter_mut().enumerate() {
        let b = base + usize::from(i < extra);
        for _ in 0..b {
            batch.push(ExampleRef { id: g, len: 0 });
            g += 1;
        }
    }
    a
}

/// Wrap raw lengths into `ExampleRef`s with ids 0..n.
pub fn make_refs(lens: &[usize]) -> Vec<ExampleRef> {
    lens.iter()
        .enumerate()
        .map(|(id, &len)| ExampleRef { id, len })
        .collect()
}

/// Identity assignment that carries real lengths.
pub fn identity_with_lens(lens: &[usize], d: usize) -> Assignment {
    let mut a = identity_assignment(lens.len(), d);
    for batch in &mut a {
        for e in batch.iter_mut() {
            e.len = lens[e.id];
        }
    }
    a
}

/// Test/bench helper: every example must appear exactly once across the
/// `d` new mini-batches.
pub fn assert_valid_assignment(a: &Assignment, n: usize, d: usize) {
    assert_eq!(a.len(), d, "assignment must have d mini-batches");
    let mut seen = vec![false; n];
    for batch in a {
        for e in batch {
            assert!(e.id < n, "example id {} out of range {n}", e.id);
            assert!(!seen[e.id], "example {} assigned twice", e.id);
            seen[e.id] = true;
        }
    }
    let missing = seen.iter().filter(|&&s| !s).count();
    assert_eq!(missing, 0, "{missing} examples unassigned");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_length_matches_eq1() {
        let b = vec![
            ExampleRef { id: 0, len: 10 },
            ExampleRef { id: 1, len: 4 },
            ExampleRef { id: 2, len: 7 },
        ];
        assert_eq!(batch_length(&b, BatchingMode::Unpadded), 21);
        assert_eq!(batch_length(&b, BatchingMode::Padded), 30);
        assert_eq!(batch_length(&[], BatchingMode::Padded), 0);
    }

    #[test]
    fn identity_assignment_is_valid_and_even() {
        let a = identity_assignment(10, 4);
        assert_valid_assignment(&a, 10, 4);
        let sizes: Vec<usize> = a.iter().map(|b| b.len()).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
    }

    #[test]
    fn identity_with_lens_carries_lengths() {
        let lens = vec![5, 6, 7, 8];
        let a = identity_with_lens(&lens, 2);
        assert_eq!(a[0][0].len, 5);
        assert_eq!(a[1][1].len, 8);
    }

    #[test]
    #[should_panic(expected = "assigned twice")]
    fn validator_catches_duplicates() {
        let a = vec![
            vec![ExampleRef { id: 0, len: 1 }],
            vec![ExampleRef { id: 0, len: 1 }],
        ];
        assert_valid_assignment(&a, 1, 2);
    }

    #[test]
    fn makespan_is_max_over_batches() {
        let a = vec![
            vec![ExampleRef { id: 0, len: 10 }],
            vec![ExampleRef { id: 1, len: 3 }, ExampleRef { id: 2, len: 4 }],
        ];
        assert_eq!(makespan(&a, BatchingMode::Unpadded), 10);
    }
}
