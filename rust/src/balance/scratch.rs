//! Reusable planning workspace: the allocation-free inner loops of the
//! Post-Balancing algorithms and the dispatcher.
//!
//! Planning one step touches the same buffer shapes every time — a
//! sorted copy of the example refs, a d-entry min-heap of batch loads,
//! per-batch sums, the d×d send-volume matrix. The paper's §6 claim
//! (dispatcher computation hides inside the prefetch overlap) only
//! holds if that computation is cheap and steady; re-allocating every
//! buffer every step both costs time and fragments the allocator under
//! the multi-phase parallel planner. [`PlanScratch`] owns all of it and
//! is reused across steps: `clear()` + `extend()` keep capacity, so a
//! warmed-up planner performs no heap allocation in its hot loops (the
//! returned [`Assignment`] itself is retained by the step plan and is
//! the one necessary allocation).
//!
//! Balancer implementations own `refs`, `heap`, `sums`, `sq_sums`,
//! `ranges`, `spill`, `ranked`, and `stats`; the dispatcher owns
//! `active`, `active_lens`, `logical_to`, and the two volume matrices.
//! The dispatcher hands the whole scratch to
//! [`super::balancer::Balancer::balance`] after `mem::take`-ing the
//! slices it is still reading.

use crate::comm::volume::VolumeMatrix;

use super::incremental::BatchStat;
use super::types::ExampleRef;

/// The reusable workspace threaded through one dispatcher's planning.
/// One per phase; the orchestrator holds three (see
/// [`crate::orchestrator::global::StepScratch`]) so phases can plan in
/// parallel without sharing.
#[derive(Clone, Debug)]
pub struct PlanScratch {
    /// Balancer-owned: sort buffer for example refs.
    pub refs: Vec<ExampleRef>,
    /// Balancer-owned: `(load, batch index)` min-heap storage.
    pub heap: Vec<(usize, usize)>,
    /// Balancer-owned: per-batch token sums (quadratic comparator).
    pub sums: Vec<usize>,
    /// Balancer-owned: per-batch squared sums (quadratic comparator).
    pub sq_sums: Vec<u128>,
    /// Balancer-owned: packed batch boundaries (padded first-fit).
    pub ranges: Vec<(usize, usize)>,
    /// Balancer-owned: overflow refs (convpad seeding).
    pub spill: Vec<ExampleRef>,
    /// Balancer-owned: previous step's `(len, id, batch)` ranking
    /// (warm-start transfer).
    pub ranked: Vec<(usize, usize, usize)>,
    /// Balancer-owned: per-batch running aggregates (warm-start
    /// transfer and repair).
    pub stats: Vec<BatchStat>,
    /// Dispatcher-owned: participating example ids.
    pub active: Vec<usize>,
    /// Dispatcher-owned: lengths of the participating examples.
    pub active_lens: Vec<usize>,
    /// Dispatcher-owned: logical destination batch per example.
    pub logical_to: Vec<usize>,
    /// Dispatcher-owned: send-volume matrix for the node-wise solver.
    pub volume: VolumeMatrix,
    /// Dispatcher-owned: send-volume matrix for All-to-All pricing.
    pub volume2: VolumeMatrix,
}

impl PlanScratch {
    pub fn new() -> PlanScratch {
        PlanScratch {
            refs: Vec::new(),
            heap: Vec::new(),
            sums: Vec::new(),
            sq_sums: Vec::new(),
            ranges: Vec::new(),
            spill: Vec::new(),
            ranked: Vec::new(),
            stats: Vec::new(),
            active: Vec::new(),
            active_lens: Vec::new(),
            logical_to: Vec::new(),
            volume: VolumeMatrix::zeros(0),
            volume2: VolumeMatrix::zeros(0),
        }
    }

    /// Fill `refs` with `(id, len)` pairs sorted descending by length
    /// (ties by id — the LPT order).
    pub fn refs_desc(&mut self, lens: &[usize]) {
        self.refs.clear();
        self.refs.extend(
            lens.iter()
                .enumerate()
                .map(|(id, &len)| ExampleRef { id, len }),
        );
        self.refs
            .sort_unstable_by(|a, b| b.len.cmp(&a.len).then(a.id.cmp(&b.id)));
    }

    /// Fill `refs` sorted ascending by length (ties by id — the padded
    /// first-fit order).
    pub fn refs_asc(&mut self, lens: &[usize]) {
        self.refs.clear();
        self.refs.extend(
            lens.iter()
                .enumerate()
                .map(|(id, &len)| ExampleRef { id, len }),
        );
        self.refs
            .sort_unstable_by(|a, b| a.len.cmp(&b.len).then(a.id.cmp(&b.id)));
    }

    /// Reset `heap` to d zero-load batches (already a valid min-heap).
    pub fn heap_zeroed(&mut self, d: usize) {
        self.heap.clear();
        self.heap.extend((0..d).map(|i| (0usize, i)));
    }
}

impl Default for PlanScratch {
    fn default() -> PlanScratch {
        PlanScratch::new()
    }
}

/// Restore the min-heap invariant downward from `i`. Entries compare
/// lexicographically on `(load, batch index)`, so ties always break on
/// the lower batch index — the same deterministic pop order as the
/// `BinaryHeap<Reverse<_>>` the algorithms previously allocated.
pub fn sift_down(heap: &mut [(usize, usize)], mut i: usize) {
    loop {
        let l = 2 * i + 1;
        let r = l + 1;
        let mut smallest = i;
        if l < heap.len() && heap[l] < heap[smallest] {
            smallest = l;
        }
        if r < heap.len() && heap[r] < heap[smallest] {
            smallest = r;
        }
        if smallest == i {
            return;
        }
        heap.swap(i, smallest);
        i = smallest;
    }
}

/// Build a min-heap in place from arbitrary entries.
pub fn heapify(heap: &mut [(usize, usize)]) {
    for i in (0..heap.len() / 2).rev() {
        sift_down(heap, i);
    }
}

/// Pop the lightest batch, push it back with `add` more load, and
/// return its index — the LPT inner step, allocation-free.
pub fn heap_assign(heap: &mut [(usize, usize)], add: usize) -> usize {
    let (load, i) = heap[0];
    heap[0] = (load + add, i);
    sift_down(heap, 0);
    i
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heap_pops_in_min_order_with_index_ties() {
        let mut s = PlanScratch::new();
        s.heap_zeroed(4);
        // All loads zero: assignment order must be 0,1,2,3.
        let order: Vec<usize> =
            (0..4).map(|_| heap_assign(&mut s.heap, 10)).collect();
        assert_eq!(order, vec![0, 1, 2, 3]);
    }

    #[test]
    fn heapify_handles_arbitrary_loads() {
        let mut heap = vec![(7, 0), (3, 1), (5, 2), (1, 3)];
        heapify(&mut heap);
        assert_eq!(heap_assign(&mut heap, 100), 3); // lightest first
        assert_eq!(heap_assign(&mut heap, 100), 1);
    }

    #[test]
    fn refs_sorting_is_deterministic() {
        let mut s = PlanScratch::new();
        s.refs_desc(&[5, 9, 5, 1]);
        let ids: Vec<usize> = s.refs.iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![1, 0, 2, 3]); // 9, then 5(id0) before 5(id2)
        s.refs_asc(&[5, 9, 5, 1]);
        let ids: Vec<usize> = s.refs.iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![3, 0, 2, 1]);
    }

    #[test]
    fn buffers_keep_capacity_across_reuse() {
        let mut s = PlanScratch::new();
        s.refs_desc(&vec![3; 1000]);
        let cap = s.refs.capacity();
        s.refs_desc(&vec![5; 500]);
        assert!(s.refs.capacity() >= cap, "capacity was released");
    }
}
