//! Exact post-balancing by branch-and-bound — the optimality oracle.
//!
//! The heuristics of §5.1 are fast but their distance from the true
//! minimax optimum `min_Π max_i f(S'_i(Π))` was unmeasured. This module
//! solves the assignment problem *exactly* (an ILP in spirit, solved by
//! branch-and-bound like [`crate::nodewise::ilp`]) so every heuristic's
//! approximation gap becomes a number the gap harness
//! ([`super::gaps`]) can track across PRs.
//!
//! Search shape:
//!
//! * items are branched in LPT order (descending length, ties by id);
//!   each node places one item into one batch, maintained as O(1)
//!   [`BatchStat`] aggregates so every Eq.-2 regime evaluates cheaply;
//! * **pruning** combines three sound lower bounds on any completion:
//!   the current costliest batch (costs only grow), the superadditive
//!   average bound `(Σ_i eval(batch_i) + Σ remaining singletons) / d`
//!   (every regime satisfies `eval(batch ∪ {l}) ≥ eval(batch) +
//!   eval({l})`), and the next item's singleton cost;
//! * **symmetry breaking**: equal-length items may only be placed in
//!   nondecreasing batch-index order, which preserves at least one
//!   optimal solution because batch costs depend only on the length
//!   multiset;
//! * **twin-batch dominance**: a candidate batch whose current
//!   aggregates equal a lower-indexed batch's is skipped. Every cost
//!   regime evaluates through [`BatchStat`] and equal aggregates stay
//!   equal under any identical sequence of future placements, so the
//!   two subtrees are cost-isomorphic — "these two items in one twin
//!   vs. swapped into the other" explores the same makespans twice.
//!   Formally: in the lexicographically-smallest optimal assignment
//!   (items in LPT order), no item is ever placed in a batch with a
//!   lower-indexed aggregate twin, else swapping the twins' remaining
//!   placements yields an equal-cost lex-smaller assignment. Subsumes
//!   the old empty-batch rule and is what pushes certified coverage to
//!   n ≈ 32 on the duplicate-heavy profiles;
//! * **last-item dominance**: the final item's cheapest placement
//!   (smallest resulting batch cost) minimizes the completed makespan
//!   — for any other batch `b`, the completed makespan is
//!   `max(M₋ᵦ, nc_b) ≥ makespan(b*)` by case analysis on whether the
//!   witness batch is `b` itself — so the last level branches exactly
//!   once;
//! * **node budget**: the search explores at most `node_budget`
//!   placements (which also bounds recursion depth), then returns the
//!   incumbent as [`IlpStatus::BestEffort`]. A completed search — or an
//!   incumbent matching the global lower bound — returns
//!   [`IlpStatus::Optimal`], a *certificate* the gap harness and the
//!   property suite rely on.
//!
//! The incumbent is seeded with the better of LPT and the identity
//! dealing under the requested cost model, so even a budget-exhausted
//! solve is never worse than `greedy` or `NoBalance` — which is what
//! lets [`IlpBalancer`] register as an ordinary (self-guarded)
//! balancer while staying total at any scale.

use super::balancer::{Balancer, CostRegime};
use super::cost::CostModel;
use super::greedy::balance_lpt_with;
use super::incremental::{lower_bound, BatchStat};
use super::scratch::PlanScratch;
use super::types::{identity_with_lens, Assignment, BatchingMode, ExampleRef};

/// Node budget of the *registered* `ilp` balancer. Deliberately small:
/// it keeps the registry-wide property sweeps (which run every balancer
/// hundreds of times in debug builds) fast, while still certifying the
/// tiny instances the oracle role needs. Oracle callers (the gap
/// harness, tests) pass their own larger budget to [`solve`].
pub const DEFAULT_NODE_BUDGET: usize = 2_000;

/// Above this `n · d` product the exact search is skipped outright and
/// the seed (best of LPT and identity) is returned as best-effort — the
/// oracle role only makes sense for small instances, and the guard
/// keeps `--balancer ilp` total at simulator scale.
pub const ILP_MAX_WORK: usize = 1 << 16;

/// Hard cap on the number of items the search will branch over
/// (recursion depth is `min(n, node_budget)`).
pub const ILP_MAX_N: usize = 1_024;

/// Whether a solve proved optimality or ran out of budget.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IlpStatus {
    /// The search completed (or the incumbent matched the global lower
    /// bound): the returned assignment is a certified optimum.
    Optimal,
    /// The node budget (or the `n·d` work guard) stopped the search:
    /// the returned assignment is the best incumbent found.
    BestEffort,
}

/// An exact-solver result: the plan plus its optimality certificate.
#[derive(Clone, Debug)]
pub struct IlpSolution {
    pub assignment: Assignment,
    pub status: IlpStatus,
    /// Placements explored (0 when the seed was already provably
    /// optimal or the work guard skipped the search).
    pub nodes: usize,
    /// The global lower bound the incumbent was certified against.
    pub lower_bound: f64,
    /// Makespan of `assignment` under the requested cost model.
    pub makespan: f64,
}

struct Search<'a> {
    cm: &'a CostModel,
    /// Items in LPT order (descending length, ties by id).
    items: Vec<ExampleRef>,
    d: usize,
    /// `singleton[k]` = eval of item k alone; `suffix[k]` = Σ_{i≥k}.
    singleton: Vec<f64>,
    suffix: Vec<f64>,
    global_lb: f64,
    budget: usize,
    nodes: usize,
    exhausted: bool,
    proven: bool,
    best_obj: f64,
    /// Sorted-item-index → batch of the best complete solution found by
    /// the search (empty until the seed is improved on).
    best_assign: Vec<usize>,
    improved: bool,
    assign: Vec<usize>,
    stats: Vec<BatchStat>,
}

impl<'a> Search<'a> {
    fn dfs(&mut self, k: usize) {
        if self.proven || self.exhausted {
            return;
        }
        if k == self.items.len() {
            let obj = self
                .stats
                .iter()
                .map(|s| s.eval(self.cm))
                .fold(0.0, f64::max);
            if obj < self.best_obj - 1e-12 {
                self.best_obj = obj;
                self.best_assign.clone_from(&self.assign);
                self.improved = true;
                if self.best_obj <= self.global_lb + 1e-9 {
                    self.proven = true;
                }
            }
            return;
        }

        // Sound completion bound from the partial assignment.
        let mut cur_max = 0.0f64;
        let mut cur_sum = 0.0f64;
        for s in &self.stats {
            let c = s.eval(self.cm);
            cur_max = cur_max.max(c);
            cur_sum += c;
        }
        let bound = cur_max
            .max((cur_sum + self.suffix[k]) / self.d as f64)
            .max(self.singleton[k]);
        if bound >= self.best_obj - 1e-9 {
            return;
        }

        let len = self.items[k].len;
        // Symmetry: equal-length items in nondecreasing batch order.
        let min_batch = if k > 0 && self.items[k - 1].len == len {
            self.assign[k - 1]
        } else {
            0
        };
        // Candidate batches, cheapest-after-placement first (good-first
        // search finds strong incumbents early). Twin-batch dominance:
        // a batch whose current aggregates equal *any* lower-indexed
        // batch's is skipped — the subtrees are cost-isomorphic (swap
        // the twins' future placements), and the lex-smallest optimum
        // always uses the lowest-indexed twin. Empty batches are all
        // twins of the first empty one, so the old empty-batch rule
        // falls out as a special case.
        let mut cands: Vec<(f64, usize)> = Vec::with_capacity(self.d);
        for b in min_batch..self.d {
            if self.stats[..b].iter().any(|s| *s == self.stats[b]) {
                continue;
            }
            let mut s = self.stats[b];
            s.add(len);
            cands.push((s.eval(self.cm), b));
        }
        cands.sort_by(|a, b| {
            a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
        });
        // Last-item dominance: the cheapest placement of the final item
        // completes with the minimal makespan, so branch it alone.
        if k + 1 == self.items.len() {
            cands.truncate(1);
        }

        for (new_cost, b) in cands {
            if self.proven || self.exhausted {
                return;
            }
            if self.nodes >= self.budget {
                self.exhausted = true;
                return;
            }
            self.nodes += 1;
            // Placing here already meets the incumbent: the whole
            // subtree is dominated (batch costs never decrease).
            if new_cost >= self.best_obj - 1e-9 {
                continue;
            }
            let before = self.stats[b];
            self.stats[b].add(len);
            self.assign[k] = b;
            self.dfs(k + 1);
            self.stats[b] = before;
        }
    }
}

/// Exact solve of `min_Π max_i cm.eval(S'_i)` over all assignments of
/// `lens` across `d` batches, within `node_budget` explored placements.
/// Deterministic pure function of its arguments (§5.2.1 still holds
/// when this runs inside a dispatcher).
pub fn solve(
    cm: &CostModel,
    lens: &[usize],
    d: usize,
    node_budget: usize,
) -> IlpSolution {
    solve_with(cm, lens, d, node_budget, &mut PlanScratch::new())
}

/// [`solve`] with a reusable scratch for the seed heuristics.
pub fn solve_with(
    cm: &CostModel,
    lens: &[usize],
    d: usize,
    node_budget: usize,
    scratch: &mut PlanScratch,
) -> IlpSolution {
    assert!(d > 0, "need at least one DP instance");
    let n = lens.len();
    if n == 0 {
        return IlpSolution {
            assignment: vec![Vec::new(); d],
            status: IlpStatus::Optimal,
            nodes: 0,
            lower_bound: 0.0,
            makespan: 0.0,
        };
    }
    let global_lb = lower_bound(cm, lens, d);

    // Seed: best of LPT and the identity dealing under `cm`. The search
    // can only improve on it, so the result is self-guarded.
    let mut seed = balance_lpt_with(lens, d, scratch);
    let mut seed_obj = cm.makespan(&seed);
    let identity = identity_with_lens(lens, d);
    let id_obj = cm.makespan(&identity);
    if id_obj < seed_obj {
        seed = identity;
        seed_obj = id_obj;
    }
    if seed_obj <= global_lb + 1e-9 {
        return IlpSolution {
            assignment: seed,
            status: IlpStatus::Optimal,
            nodes: 0,
            lower_bound: global_lb,
            makespan: seed_obj,
        };
    }
    // d >= n: spreading items one-per-batch is optimal for every
    // superadditive regime (each batch cost is a singleton cost).
    if d >= n {
        let mut a: Assignment = vec![Vec::new(); d];
        scratch.refs_desc(lens);
        for (b, &e) in scratch.refs.iter().enumerate() {
            a[b].push(e);
        }
        let obj = cm.makespan(&a);
        return IlpSolution {
            assignment: a,
            status: IlpStatus::Optimal,
            nodes: 0,
            lower_bound: global_lb,
            makespan: obj,
        };
    }
    if n.saturating_mul(d) > ILP_MAX_WORK || n > ILP_MAX_N {
        return IlpSolution {
            assignment: seed,
            status: IlpStatus::BestEffort,
            nodes: 0,
            lower_bound: global_lb,
            makespan: seed_obj,
        };
    }

    scratch.refs_desc(lens);
    let items: Vec<ExampleRef> = scratch.refs.clone();
    let singleton: Vec<f64> = items
        .iter()
        .map(|e| {
            let mut s = BatchStat::default();
            s.add(e.len);
            s.eval(cm)
        })
        .collect();
    let mut suffix = vec![0.0f64; n + 1];
    for k in (0..n).rev() {
        suffix[k] = suffix[k + 1] + singleton[k];
    }

    let mut search = Search {
        cm,
        items,
        d,
        singleton,
        suffix,
        global_lb,
        budget: node_budget,
        nodes: 0,
        exhausted: false,
        proven: false,
        best_obj: seed_obj,
        best_assign: Vec::new(),
        improved: false,
        assign: vec![0usize; n],
        stats: vec![BatchStat::default(); d],
    };
    search.dfs(0);

    let (assignment, makespan) = if search.improved {
        let mut a: Assignment = vec![Vec::new(); d];
        for (k, &b) in search.best_assign.iter().enumerate() {
            a[b].push(search.items[k]);
        }
        (a, search.best_obj)
    } else {
        (seed, seed_obj)
    };
    let status = if search.proven || !search.exhausted {
        IlpStatus::Optimal
    } else {
        IlpStatus::BestEffort
    };
    IlpSolution {
        assignment,
        status,
        nodes: search.nodes,
        lower_bound: global_lb,
        makespan,
    }
}

/// Registry entry: `ilp` (aliases `exact`, `bnb`). Linear cost regime,
/// unpadded batching — the same objective as `greedy`/`kk`, solved
/// exactly where the work guard and node budget allow, best-effort
/// (never worse than the LPT/identity seed) everywhere else.
#[derive(Clone, Copy, Debug)]
pub struct IlpBalancer {
    pub node_budget: usize,
}

impl Default for IlpBalancer {
    fn default() -> IlpBalancer {
        IlpBalancer { node_budget: DEFAULT_NODE_BUDGET }
    }
}

impl Balancer for IlpBalancer {
    fn name(&self) -> &'static str {
        "ilp"
    }

    fn batching_mode(&self) -> BatchingMode {
        BatchingMode::Unpadded
    }

    fn cost_regime(&self) -> CostRegime {
        CostRegime::Linear
    }

    fn balance(
        &self,
        lens: &[usize],
        d: usize,
        scratch: &mut PlanScratch,
    ) -> Assignment {
        solve_with(&self.cost_model(), lens, d, self.node_budget, scratch)
            .assignment
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balance::greedy::balance_lpt;
    use crate::balance::types::assert_valid_assignment;
    use crate::util::prop::check;

    const LIN: CostModel = CostModel::Linear { alpha: 1.0 };

    #[test]
    fn trivial_shapes_are_optimal() {
        let s = solve(&LIN, &[], 3, 1000);
        assert_eq!(s.status, IlpStatus::Optimal);
        assert_valid_assignment(&s.assignment, 0, 3);

        // d >= n: one item per batch, makespan = largest singleton.
        let s = solve(&LIN, &[9, 4], 5, 1000);
        assert_eq!(s.status, IlpStatus::Optimal);
        assert_valid_assignment(&s.assignment, 2, 5);
        assert!((s.makespan - 9.0).abs() < 1e-9);
    }

    #[test]
    fn beats_lpt_on_the_classic_instance() {
        // lens 8,7,6,5,4 over 2 batches: LPT gives 17, optimum is 15.
        let lpt = LIN.makespan(&balance_lpt(&[8, 7, 6, 5, 4], 2));
        let s = solve(&LIN, &[8, 7, 6, 5, 4], 2, 100_000);
        assert_eq!(s.status, IlpStatus::Optimal);
        assert!((lpt - 17.0).abs() < 1e-9);
        assert!((s.makespan - 15.0).abs() < 1e-9, "{}", s.makespan);
        assert_valid_assignment(&s.assignment, 5, 2);
    }

    #[test]
    fn uniform_lengths_keep_the_equal_split_seed() {
        let lens = vec![10usize; 24];
        let s = solve(&LIN, &lens, 4, 100_000);
        assert_eq!(s.status, IlpStatus::Optimal);
        assert_eq!(s.nodes, 0, "seed already matches the lower bound");
        let sizes: Vec<usize> =
            s.assignment.iter().map(|b| b.len()).collect();
        assert_eq!(sizes, vec![6; 4]);
    }

    #[test]
    fn budget_exhaustion_returns_a_valid_best_effort() {
        let mut g = crate::util::prop::Gen::new(3);
        let lens = g.seq_lengths(60, 3.5, 1.2);
        // Budget 1: the search can explore a single placement at most.
        let s = solve(&LIN, &lens, 5, 1);
        assert_valid_assignment(&s.assignment, 60, 5);
        assert!(
            s.makespan <= LIN.makespan(&balance_lpt(&lens, 5)) + 1e-9,
            "best-effort must never lose to the LPT seed"
        );
    }

    #[test]
    fn work_guard_skips_the_search_at_scale() {
        let mut g = crate::util::prop::Gen::new(5);
        let lens = g.seq_lengths(2_000, 4.0, 1.0);
        let s = solve(&LIN, &lens, 64, 1_000_000);
        assert_eq!(s.status, IlpStatus::BestEffort);
        assert_eq!(s.nodes, 0);
        assert_valid_assignment(&s.assignment, 2_000, 64);
    }

    #[test]
    fn dominance_certifies_a_31_item_padded_instance() {
        // All-equal lengths under a padded regime: the balanced
        // 8/8/8/7 split is optimal but sits strictly above the
        // superadditive lower bound (31 does not divide by 4), so
        // certification requires the search to *complete* — feasible
        // at n = 31 only because the equal-length rule and twin-batch
        // dominance collapse the 4^31 raw tree (the ROADMAP "n ≈ 32"
        // follow-on).
        let cm = CostModel::TransformerPadded { alpha: 1.0, beta: 0.01 };
        let lens = vec![10usize; 31];
        let s = solve(&cm, &lens, 4, 50_000);
        assert_eq!(s.status, IlpStatus::Optimal, "search must complete");
        // 8 items of padded cost 10 + 0.01·10² = 11 each.
        assert!((s.makespan - 88.0).abs() < 1e-9, "{}", s.makespan);
        assert!(
            s.makespan > s.lower_bound + 1.0,
            "certificate must be nontrivial (seed != lower bound)"
        );
    }

    #[test]
    fn twin_dominance_keeps_duplicate_heavy_optima() {
        // Two-valued batches maximize aggregate-twin collisions; the
        // pruned search must still find the exact optimum. 3+3 vs
        // 2+2+2 is the classic LPT miss (LPT gives 7, optimum 6).
        let lens = [3usize, 3, 2, 2, 2];
        let lpt = LIN.makespan(&balance_lpt(&lens, 2));
        let s = solve(&LIN, &lens, 2, 100_000);
        assert!((lpt - 7.0).abs() < 1e-9, "{lpt}");
        assert_eq!(s.status, IlpStatus::Optimal);
        assert!((s.makespan - 6.0).abs() < 1e-9, "{}", s.makespan);
        assert_valid_assignment(&s.assignment, 5, 2);
    }

    #[test]
    fn prop_solves_respect_the_lower_bound() {
        check("ilp lb sandwich", 40, |g| {
            let d = g.usize(2, 4);
            let n = g.usize(1, 12);
            let lens = g.seq_lengths(n, 3.0, 1.1);
            for cm in [
                CostModel::Linear { alpha: 1.0 },
                CostModel::TransformerUnpadded { alpha: 1.0, beta: 0.01 },
                CostModel::TransformerPadded { alpha: 1.0, beta: 0.0 },
                CostModel::ConvPadded { alpha: 1.0, lambda: 0.001 },
            ] {
                let s = solve(&cm, &lens, d, 200_000);
                assert_valid_assignment(&s.assignment, n, d);
                assert!(
                    s.makespan >= s.lower_bound - 1e-9,
                    "{cm:?}: makespan below lower bound"
                );
                assert!(
                    (s.makespan - cm.makespan(&s.assignment)).abs() < 1e-9
                );
            }
        });
    }

    #[test]
    fn deterministic() {
        let mut g = crate::util::prop::Gen::new(11);
        let lens = g.seq_lengths(20, 3.4, 1.0);
        let a = solve(&LIN, &lens, 4, 50_000);
        let b = solve(&LIN, &lens, 4, 50_000);
        assert_eq!(a.assignment, b.assignment);
        assert_eq!(a.status, b.status);
        assert_eq!(a.nodes, b.nodes);
    }

    #[test]
    fn registered_balancer_is_total_and_self_guarded() {
        let b = IlpBalancer::default();
        let mut s = PlanScratch::new();
        let mut g = crate::util::prop::Gen::new(7);
        for _ in 0..10 {
            let d = g.usize(1, 8);
            let n = g.usize(0, 80);
            let lens = g.seq_lengths(n, 3.2, 1.2);
            let a = b.balance(&lens, d, &mut s);
            assert_valid_assignment(&a, n, d);
            let cm = b.cost_model();
            assert!(
                cm.makespan(&a)
                    <= cm.makespan(&balance_lpt(&lens, d)) + 1e-9,
                "ilp worse than its own LPT seed"
            );
        }
    }
}
