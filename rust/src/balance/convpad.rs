//! Appendix Algorithm "4th": padded balancing for ConvTransformer
//! encoders.
//!
//! Conv front-ends force padded attention (no flash-attention packing),
//! so the objective is `min max_i L'_i + λ b_i max_j(l'_{i,j})²`
//! (Appendix A). The paper's algorithm seeds batches with the longest
//! sequences under the Algorithm-1 makespan bound (so each expensive
//! long sequence anchors its own batch where possible), then distributes
//! the remainder with the sum-ordered priority queue. Complexity
//! O(n log n).

use super::balancer::{Balancer, CostRegime};
use super::cost::CostModel;
use super::scratch::{heap_assign, heapify, PlanScratch};
use super::types::{batch_length, Assignment, BatchingMode};

/// Appendix Alg "4th" with a reusable scratch.
///
/// Returns the best of (a) the paper's seeded first-fit + greedy spill,
/// (b) [`super::padded::balance_padded`], and (c) the identity dealing —
/// evaluated under the ConvTransformer objective with the given λ. The
/// dispatcher keeping a cheaper arrangement when the heuristic regresses
/// is exactly the "adaptive to different scenarios" behaviour §5.1
/// requires.
pub fn balance_convpad_with(
    lens: &[usize],
    d: usize,
    lambda: f64,
    scratch: &mut PlanScratch,
) -> Assignment {
    let seeded = convpad_seeded(lens, d, scratch);
    let cm = CostModel::ConvPadded { alpha: 1.0, lambda };
    let mut best = seeded;
    let mut best_cost = cm.makespan(&best);
    for cand in [
        super::padded::balance_padded_with(lens, d, scratch),
        super::types::identity_with_lens(lens, d),
    ] {
        let c = cm.makespan(&cand);
        if c < best_cost {
            best_cost = c;
            best = cand;
        }
    }
    best
}

/// Appendix Alg "4th" (convenience wrapper over a fresh scratch).
pub fn balance_convpad(lens: &[usize], d: usize, lambda: f64) -> Assignment {
    balance_convpad_with(lens, d, lambda, &mut PlanScratch::new())
}

/// The paper's pseudocode: seed under the Alg-1 bound, spill by sum.
fn convpad_seeded(
    lens: &[usize],
    d: usize,
    scratch: &mut PlanScratch,
) -> Assignment {
    assert!(d > 0, "need at least one DP instance");
    let n = lens.len();
    if n == 0 {
        return vec![Vec::new(); d];
    }
    // Step 1: the Algorithm-1 objective value bounds per-batch token
    // sums. Simulate the LPT heap over load totals only — no batch
    // materialization needed for the bound.
    scratch.refs_desc(lens);
    scratch.heap_zeroed(d);
    for &e in &scratch.refs {
        heap_assign(&mut scratch.heap, e.len);
    }
    let bound = scratch
        .heap
        .iter()
        .map(|&(load, _)| load)
        .max()
        .unwrap_or(0)
        .max(1);

    // Step 2: seed up to d batches first-fit under the padded bound —
    // descending order means a batch's first element fixes its padded
    // length, so `(count+1) * first_len > bound` opens a new batch.
    let mut batches: Assignment = vec![Vec::new()];
    scratch.spill.clear();
    let mut iter = scratch.refs.iter().copied();
    for e in iter.by_ref() {
        let cur = batches.last_mut().unwrap();
        let pad_len = cur.first().map(|f| f.len).unwrap_or(e.len);
        if !cur.is_empty() && (cur.len() + 1) * pad_len > bound {
            if batches.len() == d {
                scratch.spill.push(e);
                break;
            }
            batches.push(vec![e]);
        } else {
            cur.push(e);
        }
    }
    scratch.spill.extend(iter);
    while batches.len() < d {
        batches.push(Vec::new());
    }

    // Step 3: distribute the remainder to the lightest batch by sum.
    scratch.heap.clear();
    scratch.heap.extend(
        batches
            .iter()
            .enumerate()
            .map(|(i, b)| (batch_length(b, BatchingMode::Unpadded), i)),
    );
    heapify(&mut scratch.heap);
    for &e in &scratch.spill {
        let i = heap_assign(&mut scratch.heap, e.len);
        batches[i].push(e);
    }
    batches
}

/// Registry entry: `convpad` (alias `alg4`).
#[derive(Clone, Copy, Debug)]
pub struct ConvPadBalancer {
    /// λ of the ConvTransformer objective.
    pub lambda: f64,
}

impl Balancer for ConvPadBalancer {
    fn name(&self) -> &'static str {
        "convpad"
    }

    fn batching_mode(&self) -> BatchingMode {
        BatchingMode::Padded
    }

    fn cost_regime(&self) -> CostRegime {
        CostRegime::ConvAttention
    }

    fn cost_model(&self) -> CostModel {
        CostModel::ConvPadded { alpha: 1.0, lambda: self.lambda }
    }

    fn balance(
        &self,
        lens: &[usize],
        d: usize,
        scratch: &mut PlanScratch,
    ) -> Assignment {
        balance_convpad_with(lens, d, self.lambda, scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balance::cost::CostModel;
    use crate::balance::types::{
        assert_valid_assignment, identity_with_lens,
    };
    use crate::util::prop::check;

    #[test]
    fn isolates_long_sequences() {
        // One giant sequence among many tiny ones: the giant should not
        // drag a large batch to its padded length.
        let mut lens = vec![100];
        lens.extend(std::iter::repeat(2).take(40));
        let a = balance_convpad(&lens, 4, 0.01);
        assert_valid_assignment(&a, 41, 4);
        let giant_batch = a
            .iter()
            .find(|b| b.iter().any(|e| e.len == 100))
            .unwrap();
        assert!(
            giant_batch.len() <= 3,
            "giant shares a batch with {} others",
            giant_batch.len() - 1
        );
    }

    #[test]
    fn empty_and_small_inputs() {
        assert_eq!(balance_convpad(&[], 3, 0.01).len(), 3);
        let a = balance_convpad(&[5], 3, 0.01);
        assert_valid_assignment(&a, 1, 3);
    }

    #[test]
    fn prop_valid() {
        check("convpad valid", 150, |g| {
            let d = g.usize(1, 10);
            let n = g.usize(0, 120);
            let lens = g.seq_lengths(n, 2.8, 1.2);
            let a = balance_convpad(&lens, d, 0.01);
            assert_valid_assignment(&a, n, d);
        });
    }

    #[test]
    fn prop_beats_identity_on_conv_objective() {
        check("convpad <= identity", 150, |g| {
            let d = g.usize(2, 8);
            let n = g.usize(d * 4, d * 16);
            let lens = g.seq_lengths(n, 3.0, 1.2);
            let cm = CostModel::ConvPadded { alpha: 1.0, lambda: 0.005 };
            let a = balance_convpad(&lens, d, 0.005);
            let i = identity_with_lens(&lens, d);
            assert!(
                cm.makespan(&a) <= cm.makespan(&i) * 1.001 + 1e-9,
                "convpad worse than identity: {} vs {}",
                cm.makespan(&a),
                cm.makespan(&i)
            );
        });
    }
}
