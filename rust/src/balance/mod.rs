//! Batch Post-Balancing algorithms (paper §5.1 + Appendix A).
//!
//! Given the sequence lengths of every example currently spread across
//! `d` DP instances, produce `d` new mini-batches minimizing the minimax
//! objective `min_Π max_i f(S'_i(Π))` of the paper, where `f` is the
//! phase's computational-cost function (Eq. 2). Because all-reduce is
//! commutative/associative, any such rearrangement is consequence-
//! invariant (§3.3) — these algorithms only ever permute examples.
//!
//! | algorithm                | batching    | cost regime        | paper |
//! |--------------------------|-------------|--------------------|-------|
//! | [`greedy::balance_lpt`]  | no padding  | β ≪ α (linear)     | Alg 1 |
//! | [`padded::balance_padded`]| padding    | β ≪ α (linear)     | Alg 2 |
//! | [`quadratic::balance_quadratic`] | no padding | β ≈ α        | Alg 4 (3rd) |
//! | [`convpad::balance_convpad`] | padding | conv-attention     | Alg 5 (4th) |
//!
//! [`prebalance`] holds the Pre-Balancing baselines the paper compares
//! against (§3.2), and [`cost`] the Eq.-2 cost functions used both by the
//! quadratic algorithms and by the cluster simulator.

pub mod convpad;
pub mod cost;
pub mod greedy;
pub mod padded;
pub mod prebalance;
pub mod quadratic;
pub mod types;

pub use cost::{CostModel, PhaseCost};
pub use types::{Assignment, BatchingMode, ExampleRef, Policy};

use crate::util::rng::Pcg64;

/// Dispatch to the right post-balancing algorithm for a policy.
///
/// `lens[g]` is the sequence length of global example `g`; `d` is the DP
/// world size. Returns the new assignment of examples to instances.
pub fn balance(policy: Policy, lens: &[usize], d: usize) -> Assignment {
    match policy {
        Policy::NoBalance => types::identity_assignment(lens.len(), d),
        Policy::GreedyUnpadded => greedy::balance_lpt(lens, d),
        Policy::BinaryPadded => padded::balance_padded(lens, d),
        Policy::QuadraticUnpadded { lambda, tolerance } => {
            quadratic::balance_quadratic(lens, d, lambda, tolerance)
        }
        Policy::ConvPadded { lambda } => {
            convpad::balance_convpad(lens, d, lambda)
        }
    }
}

/// Generate heavy-tailed sequence lengths for tests/benches (log-normal,
/// the shape §2.3 describes for production datasets: 10 .. 40k tokens).
pub fn synth_lengths(rng: &mut Pcg64, n: usize, mu: f64, sigma: f64)
    -> Vec<usize> {
    (0..n)
        .map(|_| (rng.lognormal(mu, sigma).round() as usize).clamp(1, 65_536))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balance_dispatches_all_policies() {
        let mut rng = Pcg64::new(1);
        let lens = synth_lengths(&mut rng, 64, 4.0, 1.0);
        for policy in [
            Policy::NoBalance,
            Policy::GreedyUnpadded,
            Policy::BinaryPadded,
            Policy::QuadraticUnpadded { lambda: 0.01, tolerance: 8.0 },
            Policy::ConvPadded { lambda: 0.001 },
        ] {
            let a = balance(policy, &lens, 8);
            types::assert_valid_assignment(&a, lens.len(), 8);
        }
    }
}
