//! Batch Post-Balancing algorithms (paper §5.1 + Appendix A).
//!
//! Given the sequence lengths of every example currently spread across
//! `d` DP instances, produce `d` new mini-batches minimizing the minimax
//! objective `min_Π max_i f(S'_i(Π))` of the paper, where `f` is the
//! phase's computational-cost function (Eq. 2). Because all-reduce is
//! commutative/associative, any such rearrangement is consequence-
//! invariant (§3.3) — these algorithms only ever permute examples.
//!
//! Every algorithm is a [`Balancer`] implementation resolved through
//! [`balancer::registry`] (the `--balancer` CLI flag uses the same
//! names):
//!
//! | name        | algorithm                        | batching   | cost regime    | paper |
//! |-------------|----------------------------------|------------|----------------|-------|
//! | `none`      | identity (the "w/o balance" bar) | unpadded   | —              | §8.1  |
//! | `greedy`    | [`greedy::balance_lpt`]          | no padding | β ≪ α (linear) | Alg 1 |
//! | `padded`    | [`padded::balance_padded`]       | padding    | β ≪ α (linear) | Alg 2 |
//! | `quadratic` | [`quadratic::balance_quadratic`] | no padding | β ≈ α          | Alg 4 (3rd) |
//! | `convpad`   | [`convpad::balance_convpad`]     | padding    | conv-attention | Alg 5 (4th) |
//! | `kk`        | [`kk::balance_kk`] (Karmarkar–Karp largest-differencing, LPT fallback) | no padding | β ≪ α | — |
//! | `ilp`       | [`ilp::solve`] (exact branch-and-bound, the optimality oracle for small n·d) | no padding | β ≪ α | §5.1 opt |
//! | `prebalance-*` | sampling-time baselines as post-hoc balancers | — | — | §3.2 |
//!
//! [`prebalance`] also holds the original sampling-time baseline
//! functions the paper compares against (§3.2), and [`cost`] the Eq.-2
//! cost functions used both by the quadratic algorithms and by the
//! cluster simulator. [`scratch::PlanScratch`] is the reusable
//! workspace that keeps repeated planning allocation-free (§6: the
//! dispatcher computation must stay cheap enough to hide inside the
//! prefetch overlap).
//!
//! Steady-state planning is *incremental* (DESIGN.md §Incremental
//! Planning): [`incremental`] warm-starts any balancer from the
//! previous step's assignment and locally repairs it, and [`cache`]
//! replays bit-identical plans for recurring batch shapes through a
//! quantized length-histogram sketch — both behind
//! [`Balancer::plan_incremental`], with a certified fallback to the
//! from-scratch solve.
//!
//! [`ilp`] is the exact oracle (branch-and-bound with a node budget and
//! a certified-optimal status), [`gaps`] measures every heuristic's
//! approximation gap against it across modality-incoherence profiles
//! (`BENCH_balancer_gaps.json`, gated in CI), and [`select`] picks the
//! per-phase algorithm from the registry's metadata and the model
//! configuration (`--balancer auto`).

pub mod balancer;
pub mod cache;
pub mod convpad;
pub mod cost;
pub mod gaps;
pub mod greedy;
pub mod ilp;
pub mod incremental;
pub mod kk;
pub mod padded;
pub mod prebalance;
pub mod quadratic;
pub mod scratch;
pub mod select;
pub mod types;

pub use balancer::{registry, Balancer, CostRegime};
pub use cache::{PlanCache, Sketch, DEFAULT_PLAN_CACHE_SIZE};
pub use cost::{CostModel, PhaseCost};
pub use ilp::{IlpSolution, IlpStatus};
pub use incremental::{IncrementalPlan, PlanSource, REPAIR_TOLERANCE};
pub use scratch::PlanScratch;
pub use select::{select_for_phase, PhaseTraits, Selection};
pub use types::{Assignment, BatchingMode, ExampleRef};

use crate::util::rng::Pcg64;

/// Balance with a registered algorithm by name (tests, benches, and the
/// `--balancer` CLI path all resolve through here).
pub fn balance_named(
    name: &str,
    lens: &[usize],
    d: usize,
) -> Option<Assignment> {
    let b = registry::create(name)?;
    Some(b.balance(lens, d, &mut PlanScratch::new()))
}

/// Generate heavy-tailed sequence lengths for tests/benches (log-normal,
/// the shape §2.3 describes for production datasets: 10 .. 40k tokens).
pub fn synth_lengths(rng: &mut Pcg64, n: usize, mu: f64, sigma: f64)
    -> Vec<usize> {
    (0..n)
        .map(|_| (rng.lognormal(mu, sigma).round() as usize).clamp(1, 65_536))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_registered_balancer_is_valid_on_a_shared_batch() {
        let mut rng = Pcg64::new(1);
        let lens = synth_lengths(&mut rng, 64, 4.0, 1.0);
        let mut scratch = PlanScratch::new();
        for name in registry::NAMES {
            let b = registry::must(name);
            let a = b.balance(&lens, 8, &mut scratch);
            types::assert_valid_assignment(&a, lens.len(), 8);
        }
    }

    #[test]
    fn balance_named_resolves_and_rejects() {
        let lens = vec![5, 9, 2, 7];
        let a = balance_named("greedy", &lens, 2).unwrap();
        types::assert_valid_assignment(&a, 4, 2);
        assert!(balance_named("bogus", &lens, 2).is_none());
    }

    #[test]
    fn scratch_reuse_is_deterministic_across_algorithms() {
        // Interleaving different algorithms on one scratch must not
        // leak state between calls.
        let mut rng = Pcg64::new(3);
        let lens = synth_lengths(&mut rng, 96, 3.5, 1.1);
        let mut shared = PlanScratch::new();
        for name in registry::NAMES {
            let b = registry::must(name);
            let with_shared = b.balance(&lens, 6, &mut shared);
            let with_fresh = b.balance(&lens, 6, &mut PlanScratch::new());
            assert_eq!(with_shared, with_fresh, "{name} leaked state");
        }
    }
}
