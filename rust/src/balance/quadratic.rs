//! Appendix Algorithm "3rd": unpadded balancing when β ≈ α.
//!
//! When the attention quadratic is not negligible, the objective becomes
//! `min max_i  L'_i + λ Σ_j (l'_{i,j})²` (Appendix A). The paper keeps
//! the LPT skeleton but orders the batch priority queue with a two-level
//! comparator: batches whose token sums differ by less than a tolerance
//! interval `v` are compared on their squared sums instead — trading off
//! the linear and quadratic terms. Complexity O(n log n).

use super::balancer::{Balancer, CostRegime};
use super::cost::CostModel;
use super::scratch::PlanScratch;
use super::types::{Assignment, BatchingMode};

/// The CMP function of Algorithm 4 (Appendix A): pick the batch that is
/// "smallest" — by squared sum when sums are within tolerance, else by
/// sum. Ties break on batch index for determinism.
fn lighter(
    a: (usize, u128, usize),
    b: (usize, u128, usize),
    tol: f64,
) -> bool {
    let (a_sum, a_sq, a_idx) = a;
    let (b_sum, b_sq, b_idx) = b;
    let diff = a_sum.abs_diff(b_sum) as f64;
    if diff < tol {
        (a_sq, a_idx) < (b_sq, b_idx)
    } else {
        (a_sum, a_idx) < (b_sum, b_idx)
    }
}

/// Appendix Alg "3rd" with a reusable scratch.
pub fn balance_quadratic_with(
    lens: &[usize],
    d: usize,
    _lambda: f64,
    tolerance: f64,
    scratch: &mut PlanScratch,
) -> Assignment {
    assert!(d > 0, "need at least one DP instance");
    scratch.refs_desc(lens);

    let mut batches: Assignment = vec![Vec::new(); d];
    // The comparator is tolerance-dependent and non-transitive in
    // general, so a linear scan (O(d) per insert) replaces the heap; at
    // the paper's scales (d ≤ 320) this stays well under a millisecond.
    scratch.sums.clear();
    scratch.sums.resize(d, 0);
    scratch.sq_sums.clear();
    scratch.sq_sums.resize(d, 0);
    for &e in &scratch.refs {
        let mut best = 0;
        for i in 1..d {
            if lighter(
                (scratch.sums[i], scratch.sq_sums[i], i),
                (scratch.sums[best], scratch.sq_sums[best], best),
                tolerance,
            ) {
                best = i;
            }
        }
        batches[best].push(e);
        scratch.sums[best] += e.len;
        scratch.sq_sums[best] += (e.len as u128) * (e.len as u128);
    }
    batches
}

/// Appendix Alg "3rd": LPT with quadratic-aware tie-breaking.
///
/// `lambda` = β/α (recorded in the assignment's objective via
/// [`crate::balance::cost::CostModel::TransformerUnpadded`]); `tolerance`
/// is the interval `v` within which the quadratic term decides.
pub fn balance_quadratic(
    lens: &[usize],
    d: usize,
    lambda: f64,
    tolerance: f64,
) -> Assignment {
    balance_quadratic_with(lens, d, lambda, tolerance, &mut PlanScratch::new())
}

/// Registry entry: `quadratic` (alias `alg3`).
#[derive(Clone, Copy, Debug)]
pub struct QuadraticLpt {
    /// β/α of the phase's Eq.-2 cost.
    pub lambda: f64,
    /// Tolerance interval `v` within which the quadratic term decides.
    pub tolerance: f64,
}

impl Balancer for QuadraticLpt {
    fn name(&self) -> &'static str {
        "quadratic"
    }

    fn batching_mode(&self) -> BatchingMode {
        BatchingMode::Unpadded
    }

    fn cost_regime(&self) -> CostRegime {
        CostRegime::Quadratic
    }

    fn cost_model(&self) -> CostModel {
        CostModel::TransformerUnpadded { alpha: 1.0, beta: self.lambda }
    }

    fn balance(
        &self,
        lens: &[usize],
        d: usize,
        scratch: &mut PlanScratch,
    ) -> Assignment {
        balance_quadratic_with(lens, d, self.lambda, self.tolerance, scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balance::cost::CostModel;
    use crate::balance::greedy::balance_lpt;
    use crate::balance::types::{
        assert_valid_assignment, identity_with_lens,
    };
    use crate::util::prop::check;

    #[test]
    fn zero_tolerance_matches_lpt() {
        let lens = vec![9, 8, 7, 3, 3, 2, 1, 1];
        let a = balance_quadratic(&lens, 3, 0.1, 0.0);
        let b = balance_lpt(&lens, 3);
        assert_eq!(a, b);
    }

    #[test]
    fn quadratic_tiebreak_prefers_low_sq_sum() {
        // Two batches with equal sums but different compositions: the
        // next long sequence should land in the one with lower Σl².
        // Batch A gets {10}, batch B gets {6, 4} (sum 10, sq 52 < 100).
        let lens = vec![10, 6, 4, 8];
        let a = balance_quadratic(&lens, 2, 1.0, 2.0);
        assert_valid_assignment(&a, 4, 2);
        // The 8 must join the {6,4} batch under quadratic tie-break.
        let with8: Vec<usize> = a
            .iter()
            .find(|b| b.iter().any(|e| e.len == 8))
            .unwrap()
            .iter()
            .map(|e| e.len)
            .collect();
        assert!(with8.contains(&6) || with8.contains(&4), "{a:?}");
    }

    #[test]
    fn prop_valid_assignment() {
        check("quadratic valid", 150, |g| {
            let d = g.usize(1, 10);
            let n = g.usize(0, 100);
            let lens = g.seq_lengths(n, 3.0, 1.2);
            let tol = g.f64(0.0, 50.0);
            let a = balance_quadratic(&lens, d, 0.05, tol);
            assert_valid_assignment(&a, n, d);
        });
    }

    #[test]
    fn prop_beats_identity_on_quadratic_objective() {
        check("quadratic <= identity", 150, |g| {
            let d = g.usize(2, 8);
            let n = g.usize(d * 4, d * 16);
            let lens = g.seq_lengths(n, 3.2, 1.1);
            let lambda = 0.02;
            let cm = CostModel::TransformerUnpadded {
                alpha: 1.0,
                beta: lambda,
            };
            let a = balance_quadratic(&lens, d, lambda, 16.0);
            let i = identity_with_lens(&lens, d);
            assert!(
                cm.makespan(&a) <= cm.makespan(&i) + 1e-9,
                "quadratic balance worse than identity"
            );
        });
    }

    #[test]
    fn prop_tolerance_never_catastrophic() {
        // Even with a large tolerance the result must stay within 2x of
        // plain LPT on the combined objective (it only reorders
        // near-ties).
        check("quadratic sane", 100, |g| {
            let d = g.usize(2, 6);
            let lens = g.seq_lengths(d * 10, 3.0, 1.0);
            let lambda = 0.02;
            let cm = CostModel::TransformerUnpadded {
                alpha: 1.0,
                beta: lambda,
            };
            let q = balance_quadratic(&lens, d, lambda, 1e9);
            let l = balance_lpt(&lens, d);
            assert!(cm.makespan(&q) <= 2.0 * cm.makespan(&l));
        });
    }
}
