//! Incremental planning: warm-start from the previous step's
//! assignment, then bounded local repair.
//!
//! Every step currently re-solves the assignment problem from scratch,
//! yet consecutive mini-batches are drawn from the same length
//! distribution — the step-to-step locality ROADMAP's top open item
//! asks the planner to exploit. The warm path transfers the previous
//! plan's *rank structure*: order both steps' examples by length
//! (descending, the LPT order), send the current step's rank-r example
//! to the batch the previous step's rank-r example occupied, then run a
//! bounded sequence of repair moves (heaviest-to-lightest single-item
//! migrations, then swaps) until the makespan certifies against a sound
//! lower bound.
//!
//! **Soundness gate.** The warm result is only accepted when its
//! makespan is within `1 + REPAIR_TOLERANCE` of [`lower_bound`], which
//! underestimates *every* valid assignment's makespan (and therefore
//! the from-scratch solve's). Acceptance thus proves
//!
//! ```text
//! makespan(warm) <= (1 + REPAIR_TOLERANCE) * makespan(from-scratch)
//! ```
//!
//! without ever running the from-scratch solve; rejection (or a
//! diverged batch — different size, empty phase) falls back to the cold
//! path, where the bound holds trivially. Padded cost regimes have a
//! loose lower bound (padding waste is invisible to it), so they
//! certify only on easy batches and otherwise plan cold — the fallback
//! *is* the correctness story, not a failure mode.
//!
//! All of this is deterministic in `(lens, d, prev)`: ranks tie-break
//! on id, repair scans in index order and accepts only strict
//! improvements, so every DP instance replays the identical plan
//! (§5.2.1).

use super::cost::CostModel;
use super::scratch::PlanScratch;
use super::types::{Assignment, ExampleRef};

/// Multiplicative makespan tolerance of the warm path: an accepted
/// warm-started plan is never more than this fraction worse than the
/// from-scratch solve (documented contract, pinned by
/// `rust/tests/incremental_properties.rs`).
pub const REPAIR_TOLERANCE: f64 = 0.05;

/// Maximum repair moves per warm-start before giving up and planning
/// cold. Bounds the warm path at O(budget · n/d) work past the initial
/// O(n log n) rank sort.
pub const REPAIR_MOVE_BUDGET: usize = 64;

/// Relative batch-size change past which the previous assignment is
/// considered diverged and warm-starting is skipped.
pub const DIVERGENCE_FRACTION: f64 = 0.25;

/// How a plan was produced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanSource {
    /// From-scratch solve (also the identity/`NoBalance` path).
    Cold,
    /// Warm-started from the previous assignment and locally repaired.
    Warm,
    /// Replayed bit-identically from a sketch-keyed plan cache.
    Cached,
}

/// Result of [`crate::balance::Balancer::plan_incremental`].
#[derive(Clone, Debug)]
pub struct IncrementalPlan {
    pub assignment: Assignment,
    pub source: PlanSource,
    /// Repair moves applied (0 on the cold path).
    pub repair_moves: usize,
}

/// Aggregate statistics of one mini-batch, sufficient to evaluate every
/// Eq.-2 cost regime in O(1): `(count, Σl, Σl², max l)`. Equality of
/// aggregates implies equal evals under *every* regime, now and after
/// any identical sequence of future `add`s — the property the ILP
/// solver's twin-batch dominance rule rests on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BatchStat {
    pub count: usize,
    pub sum: usize,
    pub sq: u128,
    pub max: usize,
}

/// Bounds for the vectorized sum-of-squares pass in
/// [`BatchStat::of_slice`]: with every length ≤ 2²⁰ and fewer than 2²³
/// members, per-lane u64 accumulation cannot overflow (2⁴⁰ · 2²³ =
/// 2⁶³). Production lengths are clamped to 2¹⁶ by the generator, so
/// real workloads always take the fast path; anything larger falls
/// back to scalar u128 accumulation.
const SQ_FAST_MAX_LEN: usize = 1 << 20;
const SQ_FAST_MAX_COUNT: usize = 1 << 23;

impl BatchStat {
    #[inline]
    pub fn add(&mut self, len: usize) {
        self.count += 1;
        self.sum += len;
        self.sq += (len as u128) * (len as u128);
        self.max = self.max.max(len);
    }

    /// Aggregate a whole length slice: flat SoA accumulation over
    /// 4-wide chunks — independent lanes, no per-item branching, so
    /// the loops autovectorize — exactly equal to folding [`Self::add`]
    /// over the slice (integer arithmetic throughout; a unit test pins
    /// the equivalence).
    pub fn of_slice(lens: &[usize]) -> BatchStat {
        let mut sum = [0u64; 4];
        let mut max = [0usize; 4];
        let mut chunks = lens.chunks_exact(4);
        for c in &mut chunks {
            sum[0] += c[0] as u64;
            sum[1] += c[1] as u64;
            sum[2] += c[2] as u64;
            sum[3] += c[3] as u64;
            max[0] = max[0].max(c[0]);
            max[1] = max[1].max(c[1]);
            max[2] = max[2].max(c[2]);
            max[3] = max[3].max(c[3]);
        }
        let mut s = BatchStat {
            count: lens.len(),
            sum: (sum[0] + sum[1] + sum[2] + sum[3]) as usize,
            sq: 0,
            max: max[0].max(max[1]).max(max[2]).max(max[3]),
        };
        for &l in chunks.remainder() {
            s.sum += l;
            s.max = s.max.max(l);
        }
        if s.max <= SQ_FAST_MAX_LEN && s.count < SQ_FAST_MAX_COUNT {
            let mut sq = [0u64; 4];
            let mut chunks = lens.chunks_exact(4);
            for c in &mut chunks {
                sq[0] += (c[0] as u64) * (c[0] as u64);
                sq[1] += (c[1] as u64) * (c[1] as u64);
                sq[2] += (c[2] as u64) * (c[2] as u64);
                sq[3] += (c[3] as u64) * (c[3] as u64);
            }
            s.sq = sq.iter().map(|&x| x as u128).sum();
            for &l in chunks.remainder() {
                s.sq += (l as u128) * (l as u128);
            }
        } else {
            for &l in lens {
                s.sq += (l as u128) * (l as u128);
            }
        }
        s
    }

    /// Remove one member of length `len`. `next_max` is the batch's
    /// maximum after removal *when `len` was the unique maximum* (the
    /// caller computes it from a top-2 scan); it is ignored otherwise.
    #[inline]
    pub fn remove(&mut self, len: usize, next_max: usize) {
        self.count -= 1;
        self.sum -= len;
        self.sq -= (len as u128) * (len as u128);
        if self.count == 0 {
            self.max = 0;
        } else if len >= self.max {
            self.max = next_max;
        }
    }

    /// Evaluate the batch under `cm` — exactly [`CostModel::eval`] on
    /// the member list, computed from the aggregates.
    pub fn eval(&self, cm: &CostModel) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let b = self.count as f64;
        let sum = self.sum as f64;
        let sq = self.sq as f64;
        let max = self.max as f64;
        match *cm {
            CostModel::Linear { alpha } => alpha * sum,
            CostModel::TransformerUnpadded { alpha, beta } => {
                alpha * sum + beta * sq
            }
            CostModel::TransformerPadded { alpha, beta } => {
                alpha * b * max + beta * b * max * max
            }
            CostModel::ConvPadded { alpha, lambda } => {
                alpha * b * max + lambda * b * max * max
            }
        }
    }
}

/// A lower bound on the makespan of **every** valid assignment of
/// `lens` over `d` batches under `cm`:
///
/// * each of our cost regimes is superadditive over batch members
///   (`eval(batch) >= Σ eval({member})`), so the total singleton cost
///   divided by `d` bounds the heaviest batch from below;
/// * eval is monotone under adding members, so the costliest singleton
///   bounds whichever batch contains it.
pub fn lower_bound(cm: &CostModel, lens: &[usize], d: usize) -> f64 {
    let s = BatchStat::of_slice(lens);
    if s.count == 0 {
        return 0.0;
    }
    // Every regime's singleton cost has the closed form A·l + B·l²
    // with A, B ≥ 0 (the padded regimes degenerate to b = 1, max = l),
    // so the total singleton cost is A·Σl + B·Σl² and the costliest
    // singleton sits at max l — O(1) from the slice aggregates instead
    // of a BatchStat per element.
    let (a, b) = match *cm {
        CostModel::Linear { alpha } => (alpha, 0.0),
        CostModel::TransformerUnpadded { alpha, beta } => (alpha, beta),
        CostModel::TransformerPadded { alpha, beta } => (alpha, beta),
        CostModel::ConvPadded { alpha, lambda } => (alpha, lambda),
    };
    let singleton_sum = a * s.sum as f64 + b * s.sq as f64;
    let max = s.max as f64;
    let singleton_max = a * max + b * max * max;
    singleton_max.max(singleton_sum / d.max(1) as f64)
}

/// Makespan of the identity (`NoBalance`) dealing — contiguous chunks,
/// as [`super::types::identity_with_lens`] produces — without
/// materializing it.
pub fn identity_makespan(cm: &CostModel, lens: &[usize], d: usize) -> f64 {
    if d == 0 {
        return 0.0;
    }
    let n = lens.len();
    let (base, extra) = (n / d, n % d);
    let mut worst = 0.0f64;
    let mut start = 0;
    for i in 0..d {
        let b = base + usize::from(i < extra);
        let s = BatchStat::of_slice(&lens[start..start + b]);
        worst = worst.max(s.eval(cm));
        start += b;
    }
    worst
}

/// `(max, multiplicity of max, second distinct value)` of a batch.
fn top2(batch: &[ExampleRef]) -> (usize, usize, usize) {
    let mut m1 = 0usize;
    let mut c1 = 0usize;
    let mut m2 = 0usize;
    for e in batch {
        if e.len > m1 {
            m2 = m1;
            m1 = e.len;
            c1 = 1;
        } else if e.len == m1 && m1 > 0 {
            c1 += 1;
        } else if e.len > m2 {
            m2 = e.len;
        }
    }
    (m1, c1, m2)
}

/// The batch maximum after removing one member of length `len`, given a
/// top-2 scan `(m1, c1, m2)`.
#[inline]
fn max_after_remove(len: usize, m1: usize, c1: usize, m2: usize) -> usize {
    if len < m1 || c1 > 1 {
        m1
    } else {
        m2
    }
}

/// Warm-start `lens` from `prev` and locally repair under the default
/// [`REPAIR_TOLERANCE`] band. Returns the repaired assignment and the
/// number of moves applied, or `None` when the batch diverged or repair
/// could not certify the tolerance band (the caller then plans cold).
pub fn warm_start(
    cm: &CostModel,
    lens: &[usize],
    d: usize,
    prev: &Assignment,
    scratch: &mut PlanScratch,
) -> Option<(Assignment, usize)> {
    warm_start_with(cm, lens, d, prev, scratch, REPAIR_TOLERANCE)
}

/// [`warm_start`] with an explicit tolerance band (the
/// `PlanOptions::tolerance` knob): an accepted warm plan's makespan is
/// certified within `1 + tolerance` of the sound lower bound, and hence
/// of the from-scratch solve. `0.0` accepts only provably-optimal warm
/// plans; larger values trade plan quality for fewer cold solves.
pub fn warm_start_with(
    cm: &CostModel,
    lens: &[usize],
    d: usize,
    prev: &Assignment,
    scratch: &mut PlanScratch,
    tolerance: f64,
) -> Option<(Assignment, usize)> {
    let n = lens.len();
    if n == 0 || d == 0 || prev.len() != d {
        return None;
    }
    let prev_n: usize = prev.iter().map(|b| b.len()).sum();
    if prev_n == 0 {
        return None;
    }
    if n.abs_diff(prev_n) as f64 > DIVERGENCE_FRACTION * prev_n as f64 {
        return None;
    }

    // Previous step's rank → batch map, ranks in LPT order. `ranked`
    // and `stats` live in the scratch arena: warmed-up sessions reuse
    // their capacity, keeping the warm path allocation-free apart from
    // the returned assignment itself.
    scratch.ranked.clear();
    for (b, batch) in prev.iter().enumerate() {
        for e in batch {
            scratch.ranked.push((e.len, e.id, b));
        }
    }
    scratch
        .ranked
        .sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));

    // Transfer: the current rank-r example goes where the previous
    // rank-r example went; overflow ranks go to the cheapest batch.
    scratch.refs_desc(lens);
    let mut assignment: Assignment = vec![Vec::new(); d];
    scratch.stats.clear();
    scratch.stats.resize(d, BatchStat::default());
    for (rank, &e) in scratch.refs.iter().enumerate() {
        let batch = if rank < prev_n {
            scratch.ranked[rank].2
        } else {
            let mut best = 0;
            let mut best_cost = f64::INFINITY;
            for (i, s) in scratch.stats.iter().enumerate() {
                let c = s.eval(cm);
                if c < best_cost {
                    best_cost = c;
                    best = i;
                }
            }
            best
        };
        assignment[batch].push(e);
        scratch.stats[batch].add(e.len);
    }

    let moves = repair(cm, &mut assignment, &mut scratch.stats);

    let makespan = scratch
        .stats
        .iter()
        .map(|s| s.eval(cm))
        .fold(0.0, f64::max);
    let lb = lower_bound(cm, lens, d);
    if makespan <= lb * (1.0 + tolerance) + 1e-9 {
        Some((assignment, moves))
    } else {
        None
    }
}

/// Bounded local repair: move (or swap) items from the costliest batch
/// toward the cheapest while the pairwise maximum strictly improves.
fn repair(
    cm: &CostModel,
    assignment: &mut Assignment,
    stats: &mut [BatchStat],
) -> usize {
    let d = assignment.len();
    if d < 2 {
        return 0;
    }
    let mut moves = 0usize;
    while moves < REPAIR_MOVE_BUDGET {
        let mut hi = 0;
        let mut lo = 0;
        let mut hi_cost = f64::NEG_INFINITY;
        let mut lo_cost = f64::INFINITY;
        for (i, s) in stats.iter().enumerate() {
            let c = s.eval(cm);
            if c > hi_cost {
                hi_cost = c;
                hi = i;
            }
            if c < lo_cost {
                lo_cost = c;
                lo = i;
            }
        }
        if hi == lo || assignment[hi].is_empty() {
            break;
        }
        let (m1, c1, m2) = top2(&assignment[hi]);

        // Best single-item migration hi → lo.
        let mut best: Option<(usize, f64)> = None;
        for (k, e) in assignment[hi].iter().enumerate() {
            let mut sh = stats[hi];
            sh.remove(e.len, max_after_remove(e.len, m1, c1, m2));
            let mut sl = stats[lo];
            sl.add(e.len);
            let pair = sh.eval(cm).max(sl.eval(cm));
            let improves = match best {
                None => true,
                Some((_, b)) => pair < b,
            };
            if pair + 1e-9 < hi_cost && improves {
                best = Some((k, pair));
            }
        }
        if let Some((k, _)) = best {
            let e = assignment[hi].remove(k);
            stats[hi].remove(e.len, max_after_remove(e.len, m1, c1, m2));
            stats[lo].add(e.len);
            assignment[lo].push(e);
            moves += 1;
            continue;
        }

        // No improving migration: best swap hi[k] ↔ lo[j].
        let (l1, lc1, l2) = top2(&assignment[lo]);
        let mut best_swap: Option<(usize, usize, f64)> = None;
        for (k, eh) in assignment[hi].iter().enumerate() {
            for (j, el) in assignment[lo].iter().enumerate() {
                if el.len >= eh.len {
                    continue; // only swaps that lighten hi
                }
                let mut sh = stats[hi];
                sh.remove(eh.len, max_after_remove(eh.len, m1, c1, m2));
                sh.add(el.len);
                let mut sl = stats[lo];
                sl.remove(el.len, max_after_remove(el.len, l1, lc1, l2));
                sl.add(eh.len);
                let pair = sh.eval(cm).max(sl.eval(cm));
                let improves = match best_swap {
                    None => true,
                    Some((_, _, b)) => pair < b,
                };
                if pair + 1e-9 < hi_cost && improves {
                    best_swap = Some((k, j, pair));
                }
            }
        }
        match best_swap {
            Some((k, j, _)) => {
                let eh = assignment[hi][k];
                let el = assignment[lo][j];
                stats[hi]
                    .remove(eh.len, max_after_remove(eh.len, m1, c1, m2));
                stats[hi].add(el.len);
                stats[lo]
                    .remove(el.len, max_after_remove(el.len, l1, lc1, l2));
                stats[lo].add(eh.len);
                assignment[hi][k] = el;
                assignment[lo][j] = eh;
                moves += 1;
            }
            None => break,
        }
    }
    moves
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balance::greedy::balance_lpt;
    use crate::balance::types::{
        assert_valid_assignment, identity_with_lens, make_refs,
    };
    use crate::util::prop::check;

    const LIN: CostModel = CostModel::Linear { alpha: 1.0 };

    #[test]
    fn batch_stat_eval_matches_cost_model_eval() {
        let batch = make_refs(&[3, 5, 5, 11]);
        for cm in [
            CostModel::Linear { alpha: 2.0 },
            CostModel::TransformerUnpadded { alpha: 1.0, beta: 0.03 },
            CostModel::TransformerPadded { alpha: 1.0, beta: 0.1 },
            CostModel::ConvPadded { alpha: 1.0, lambda: 0.01 },
        ] {
            let mut s = BatchStat::default();
            for e in &batch {
                s.add(e.len);
            }
            assert!(
                (s.eval(&cm) - cm.eval(&batch)).abs() < 1e-9,
                "{cm:?}: {} vs {}",
                s.eval(&cm),
                cm.eval(&batch)
            );
        }
    }

    #[test]
    fn of_slice_matches_folding_add() {
        check("of_slice ≡ fold(add)", 60, |g| {
            let n = g.usize(0, 200);
            let mut lens = g.seq_lengths(n, 3.4, 1.3);
            if n > 0 && g.bool() {
                // Force the scalar u128 fallback at least sometimes.
                let i = g.usize(0, n);
                lens[i] = SQ_FAST_MAX_LEN + g.usize(1, 1000);
            }
            let mut want = BatchStat::default();
            for &l in &lens {
                want.add(l);
            }
            assert_eq!(BatchStat::of_slice(&lens), want);
        });
    }

    #[test]
    fn batch_stat_remove_handles_duplicate_maxima() {
        let mut s = BatchStat::default();
        for l in [5, 9, 9, 2] {
            s.add(l);
        }
        // Removing one of the two 9s keeps max 9.
        s.remove(9, 9);
        assert_eq!(s.max, 9);
        assert_eq!(s.sum, 16);
        // Removing the last 9 drops max to the caller-provided 5.
        s.remove(9, 5);
        assert_eq!(s.max, 5);
        assert_eq!(s.count, 2);
    }

    #[test]
    fn lower_bound_is_sound_for_every_regime() {
        check("lb soundness", 80, |g| {
            let d = g.usize(1, 8);
            let n = g.usize(1, 80);
            let lens = g.seq_lengths(n, 3.2, 1.2);
            let a = balance_lpt(&lens, d);
            for cm in [
                CostModel::Linear { alpha: 1.0 },
                CostModel::TransformerUnpadded { alpha: 1.0, beta: 0.01 },
                CostModel::TransformerPadded { alpha: 1.0, beta: 0.0 },
                CostModel::ConvPadded { alpha: 1.0, lambda: 0.001 },
            ] {
                let lb = lower_bound(&cm, &lens, d);
                assert!(
                    cm.makespan(&a) >= lb - 1e-9,
                    "{cm:?}: makespan {} below lower bound {lb}",
                    cm.makespan(&a)
                );
            }
        });
    }

    #[test]
    fn identity_makespan_matches_materialized_identity() {
        check("identity makespan", 60, |g| {
            let d = g.usize(1, 9);
            let lens = g.seq_lengths(g.usize(0, 70), 3.0, 1.0);
            let want = LIN.makespan(&identity_with_lens(&lens, d));
            let got = identity_makespan(&LIN, &lens, d);
            assert!((want - got).abs() < 1e-9, "{want} vs {got}");
        });
    }

    #[test]
    fn warm_start_rejects_diverged_batches() {
        let mut s = PlanScratch::new();
        let prev = balance_lpt(&[10, 12, 9, 11, 10, 12], 2);
        // Empty current phase.
        assert!(warm_start(&LIN, &[], 2, &prev, &mut s).is_none());
        // Single example vs a 6-example history.
        assert!(warm_start(&LIN, &[10], 2, &prev, &mut s).is_none());
        // d mismatch.
        assert!(warm_start(
            &LIN,
            &[10, 11, 12, 9, 10, 12],
            3,
            &prev,
            &mut s
        )
        .is_none());
        // Empty history.
        assert!(warm_start(
            &LIN,
            &[10, 11, 12, 9, 10, 12],
            2,
            &vec![Vec::new(); 2],
            &mut s
        )
        .is_none());
    }

    #[test]
    fn warm_start_transfers_and_certifies_similar_batches() {
        check("warm transfer", 60, |g| {
            let d = g.usize(2, 8);
            let n = d * g.usize(8, 24);
            let lens0 = g.seq_lengths(n, 3.5, 0.9);
            let lens1 = g.seq_lengths(n, 3.5, 0.9);
            let prev = balance_lpt(&lens0, d);
            let mut s = PlanScratch::new();
            if let Some((a, _)) = warm_start(&LIN, &lens1, d, &prev, &mut s)
            {
                assert_valid_assignment(&a, n, d);
                let lb = lower_bound(&LIN, &lens1, d);
                assert!(
                    LIN.makespan(&a)
                        <= lb * (1.0 + REPAIR_TOLERANCE) + 1e-9
                );
            }
        });
    }

    #[test]
    fn repair_fixes_a_deliberately_lopsided_warm_seed() {
        // prev deals everything to batch 0; warm-start inherits the
        // lopsided shape and repair must redistribute it.
        let lens: Vec<usize> = vec![10; 40];
        let prev: Assignment =
            vec![make_refs(&lens), Vec::new(), Vec::new(), Vec::new()];
        let mut s = PlanScratch::new();
        let (a, moves) =
            warm_start(&LIN, &lens, 4, &prev, &mut s).expect("certifies");
        assert_valid_assignment(&a, 40, 4);
        assert!(moves > 0, "repair should have moved items");
        assert!(LIN.makespan(&a) <= 110.0, "{}", LIN.makespan(&a));
    }

    #[test]
    fn tolerance_widens_and_narrows_the_acceptance_gate() {
        // lens [3,3,3,2] over 2 batches: lb = 5.5, best reachable
        // makespan 6 (gap ~9.1%). The default 5% band rejects the warm
        // plan; a 20% band accepts it; a 0% band only ever accepts
        // provably-optimal warm plans.
        let lens = [3usize, 3, 3, 2];
        let prev = balance_lpt(&lens, 2);
        let mut s = PlanScratch::new();
        assert!(warm_start(&LIN, &lens, 2, &prev, &mut s).is_none());
        let (a, _) =
            warm_start_with(&LIN, &lens, 2, &prev, &mut s, 0.20)
                .expect("20% band must accept makespan 6 vs lb 5.5");
        assert_valid_assignment(&a, 4, 2);
        assert!(LIN.makespan(&a) <= 5.5 * 1.20 + 1e-9);
        assert!(
            warm_start_with(&LIN, &lens, 2, &prev, &mut s, 0.0).is_none(),
            "0% band must reject a warm plan above the lower bound"
        );
        // An exactly-balanceable batch certifies even at tolerance 0.
        let lens = [4usize, 4, 4, 4];
        let prev = balance_lpt(&lens, 2);
        let (a, _) =
            warm_start_with(&LIN, &lens, 2, &prev, &mut s, 0.0)
                .expect("an optimal warm plan certifies at tolerance 0");
        assert!((LIN.makespan(&a) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn warm_start_is_deterministic() {
        let mut g = crate::util::prop::Gen::new(5);
        let lens0 = g.seq_lengths(96, 3.4, 1.1);
        let lens1 = g.seq_lengths(96, 3.4, 1.1);
        let prev = balance_lpt(&lens0, 6);
        let a = warm_start(&LIN, &lens1, 6, &prev, &mut PlanScratch::new());
        let b = warm_start(&LIN, &lens1, 6, &prev, &mut PlanScratch::new());
        match (a, b) {
            (Some((x, mx)), Some((y, my))) => {
                assert_eq!(x, y);
                assert_eq!(mx, my);
            }
            (None, None) => {}
            _ => panic!("warm_start nondeterministic"),
        }
    }
}
