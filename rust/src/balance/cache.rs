//! Sketch-keyed plan cache: recurring batch shapes skip the solve.
//!
//! Consecutive training steps draw from the same dataset distribution,
//! and curriculum/replay pipelines revisit the *same* batch shapes
//! outright. [`PlanCache`] exploits the second fact: plans are stored
//! under a quantized length-histogram sketch (log-bucketed counts,
//! FNV-hashed) and verified against the exact planning input, so a hit
//! replays an earlier solve **bit-identically** — determinism (§5.2.1:
//! every DP instance must reach the same plan independently) is
//! preserved by construction.
//!
//! Two-level keying:
//!
//! * the **sketch** ([`Sketch`]) is the fast bucket key — a 64-bit FNV
//!   hash of the log₂-bucketed length histogram plus `n` and `d`. Two
//!   batches with the same shape land in the same bucket cheaply;
//! * the **exact key** (a caller-packed `&[u64]` word slice) resolves
//!   quantization collisions: an entry only hits when its full planning
//!   input matches word-for-word. Anything less would hand back a plan
//!   for *different* lengths and silently break the §3.3
//!   consequence-invariance argument.
//!
//! Eviction is least-recently-used over a small fixed capacity, so the
//! cache holds the working set of recurring shapes and forgets one-off
//! batches. Capacity 0 disables the cache entirely (every lookup
//! misses, inserts are dropped).

/// Number of log₂ histogram buckets. Sequence lengths are clamped to
/// 65 536 by the generator (§2.3 production range), so lengths 1..=2¹⁶
/// occupy buckets 1..=17; bucket 0 counts zero-length entries and the
/// last bucket absorbs anything longer.
pub const SKETCH_BUCKETS: usize = 18;

/// Default capacity for planning caches (per phase and per step).
pub const DEFAULT_PLAN_CACHE_SIZE: usize = 32;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Word-wise FNV-1a step.
#[inline]
fn fnv1a(h: u64, word: u64) -> u64 {
    (h ^ word).wrapping_mul(FNV_PRIME)
}

/// Histogram bucket for one length: floor(log2(l)) + 1 for l > 0,
/// bucket 0 for l == 0, last bucket absorbs anything over-range.
#[inline]
fn bucket(l: usize) -> usize {
    ((usize::BITS - l.leading_zeros()) as usize).min(SKETCH_BUCKETS - 1)
}

/// The quantized length-histogram sketch: the cache's bucket key.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Sketch(pub u64);

impl Sketch {
    /// Sketch a length slice for a `d`-way planning problem.
    ///
    /// Slice form of [`Sketch::of_iter`] (the two must agree hash-for-
    /// hash; a unit test pins it). The bucket loop counts into four
    /// sub-histograms over 4-length chunks — no serial dependence on a
    /// single counter array, so the loop pipelines/vectorizes — and
    /// merges them afterwards. Counts are order-free, so the merged
    /// histogram is exactly the streaming one.
    pub fn of(lens: &[usize], d: usize) -> Sketch {
        let mut sub = [[0u32; SKETCH_BUCKETS]; 4];
        let mut chunks = lens.chunks_exact(4);
        for c in &mut chunks {
            sub[0][bucket(c[0])] += 1;
            sub[1][bucket(c[1])] += 1;
            sub[2][bucket(c[2])] += 1;
            sub[3][bucket(c[3])] += 1;
        }
        let mut hist = sub[0];
        for s in &sub[1..] {
            for (h, &c) in hist.iter_mut().zip(s.iter()) {
                *h += c;
            }
        }
        for &l in chunks.remainder() {
            hist[bucket(l)] += 1;
        }
        finish(&hist, lens.len() as u64, d)
    }

    /// Sketch an arbitrary length stream (used by the step-level cache,
    /// which sketches derived per-example lengths without staging them).
    pub fn of_iter(lens: impl Iterator<Item = usize>, d: usize) -> Sketch {
        let mut hist = [0u32; SKETCH_BUCKETS];
        let mut n = 0u64;
        for l in lens {
            hist[bucket(l)] += 1;
            n += 1;
        }
        finish(&hist, n, d)
    }
}

/// Fold the 20-word sketch message (`d`, `n`, the 18 bucket counts)
/// four words per FNV round: four independently-seeded hash lanes
/// consume the words round-robin — breaking the serial xor-multiply
/// chain so a superscalar core runs the lanes in parallel — then one
/// final serial fold combines the lanes into the sketch value.
#[inline]
fn finish(hist: &[u32; SKETCH_BUCKETS], n: u64, d: usize) -> Sketch {
    let mut words = [0u64; SKETCH_BUCKETS + 2];
    words[0] = d as u64;
    words[1] = n;
    for (w, &c) in words[2..].iter_mut().zip(hist.iter()) {
        *w = c as u64;
    }
    let mut lanes = [
        FNV_OFFSET,
        FNV_OFFSET ^ FNV_PRIME,
        FNV_OFFSET.rotate_left(17),
        FNV_OFFSET.rotate_left(31),
    ];
    for chunk in words.chunks_exact(4) {
        lanes[0] = fnv1a(lanes[0], chunk[0]);
        lanes[1] = fnv1a(lanes[1], chunk[1]);
        lanes[2] = fnv1a(lanes[2], chunk[2]);
        lanes[3] = fnv1a(lanes[3], chunk[3]);
    }
    let mut h = FNV_OFFSET;
    for lane in lanes {
        h = fnv1a(h, lane);
    }
    Sketch(h)
}

#[derive(Clone, Debug)]
struct Entry<V> {
    sketch: u64,
    key: Vec<u64>,
    value: V,
    /// LRU stamp: monotone access counter.
    stamp: u64,
}

/// An LRU plan cache bucketed by [`Sketch`] and verified by an exact
/// key, generic over the cached plan type (balancer-local assignments
/// at the phase level, full step plans at the orchestrator level).
#[derive(Clone, Debug)]
pub struct PlanCache<V> {
    entries: Vec<Entry<V>>,
    capacity: usize,
    clock: u64,
    /// Exact hits served.
    pub hits: u64,
    /// Lookups that found no exact entry.
    pub misses: u64,
}

impl<V: Clone> PlanCache<V> {
    /// A cache holding at most `capacity` plans (0 = disabled).
    pub fn new(capacity: usize) -> PlanCache<V> {
        PlanCache {
            entries: Vec::with_capacity(capacity.min(64)),
            capacity,
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Fraction of lookups served from the cache.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Exact lookup: sketch bucket first, then word-for-word key
    /// comparison. A `Some` is a bit-identical replay of the plan an
    /// earlier identical input produced.
    pub fn lookup(&mut self, sketch: Sketch, key: &[u64]) -> Option<V> {
        if self.capacity == 0 {
            return None;
        }
        self.clock += 1;
        for e in &mut self.entries {
            if e.sketch == sketch.0 && e.key == key {
                e.stamp = self.clock;
                self.hits += 1;
                return Some(e.value.clone());
            }
        }
        self.misses += 1;
        None
    }

    /// Insert (or refresh) a plan, evicting the least-recently-used
    /// entry when at capacity.
    pub fn insert(&mut self, sketch: Sketch, key: &[u64], value: V) {
        if self.capacity == 0 {
            return;
        }
        self.clock += 1;
        if let Some(e) = self
            .entries
            .iter_mut()
            .find(|e| e.sketch == sketch.0 && e.key == key)
        {
            e.value = value;
            e.stamp = self.clock;
            return;
        }
        if self.entries.len() >= self.capacity {
            let lru = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(i, _)| i)
                .expect("non-empty at capacity");
            self.entries.swap_remove(lru);
        }
        self.entries.push(Entry {
            sketch: sketch.0,
            key: key.to_vec(),
            value,
            stamp: self.clock,
        });
    }

    /// Iterate the cache contents for serialization (archive export):
    /// `(sketch, exact key, value, LRU stamp)` in storage order. Storage
    /// order is not recency order — stamps carry the LRU state.
    pub fn entries(&self) -> impl Iterator<Item = (Sketch, &[u64], &V, u64)> {
        self.entries
            .iter()
            .map(|e| (Sketch(e.sketch), e.key.as_slice(), &e.value, e.stamp))
    }

    /// Current LRU clock (monotone access counter), for serialization.
    pub fn clock(&self) -> u64 {
        self.clock
    }

    /// Rebuild a cache from serialized entries (archive load).
    ///
    /// The loader's `capacity` may differ from the exporter's: when the
    /// archive holds more entries than fit, the most recently used
    /// (highest-stamp) entries win, mirroring what LRU eviction would
    /// have kept. Hit/miss counters restart at zero — they describe the
    /// *current* process, not the archived one. The clock resumes at
    /// max(archived clock, highest stamp) so future stamps stay monotone.
    pub fn restore(
        capacity: usize,
        clock: u64,
        entries: Vec<(u64, Vec<u64>, V, u64)>,
    ) -> PlanCache<V> {
        let mut entries = entries;
        if capacity == 0 {
            entries.clear();
        } else if entries.len() > capacity {
            entries.sort_by_key(|(_, _, _, stamp)| *stamp);
            entries.drain(..entries.len() - capacity);
        }
        let max_stamp = entries
            .iter()
            .map(|(_, _, _, stamp)| *stamp)
            .max()
            .unwrap_or(0);
        PlanCache {
            entries: entries
                .into_iter()
                .map(|(sketch, key, value, stamp)| Entry {
                    sketch,
                    key,
                    value,
                    stamp,
                })
                .collect(),
            capacity,
            clock: clock.max(max_stamp),
            hits: 0,
            misses: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sketch_ignores_order_but_not_shape() {
        let a = Sketch::of(&[4, 9, 300], 4);
        let b = Sketch::of(&[300, 4, 9], 4);
        assert_eq!(a, b, "histogram sketch must be order-invariant");
        let c = Sketch::of(&[4, 9, 3000], 4);
        assert_ne!(a, c, "different buckets must change the sketch");
        let d2 = Sketch::of(&[4, 9, 300], 8);
        assert_ne!(a, d2, "d is part of the key");
    }

    #[test]
    fn sketch_iter_matches_slice() {
        let lens = vec![1usize, 7, 64, 65_536, 0];
        assert_eq!(
            Sketch::of(&lens, 3),
            Sketch::of_iter(lens.iter().copied(), 3)
        );
    }

    #[test]
    fn hit_requires_exact_key_match() {
        let mut c: PlanCache<usize> = PlanCache::new(4);
        let lens_a = [5u64, 6, 7];
        let lens_b = [5u64, 6, 8]; // same log buckets as a
        let sk = Sketch::of(&[5, 6, 7], 2);
        let sk_b = Sketch::of(&[5, 6, 8], 2);
        assert_eq!(sk, sk_b, "test premise: shapes share a sketch");
        c.insert(sk, &lens_a, 41);
        assert_eq!(c.lookup(sk, &lens_a), Some(41));
        assert_eq!(
            c.lookup(sk_b, &lens_b),
            None,
            "sketch collision must not alias different inputs"
        );
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn lru_evicts_the_stalest_entry() {
        let mut c: PlanCache<u32> = PlanCache::new(2);
        let s = |x: u64| Sketch(x);
        c.insert(s(1), &[1], 10);
        c.insert(s(2), &[2], 20);
        assert_eq!(c.lookup(s(1), &[1]), Some(10)); // refresh entry 1
        c.insert(s(3), &[3], 30); // evicts entry 2
        assert_eq!(c.lookup(s(2), &[2]), None);
        assert_eq!(c.lookup(s(1), &[1]), Some(10));
        assert_eq!(c.lookup(s(3), &[3]), Some(30));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn zero_capacity_disables_the_cache() {
        let mut c: PlanCache<u32> = PlanCache::new(0);
        c.insert(Sketch(1), &[1], 1);
        assert_eq!(c.lookup(Sketch(1), &[1]), None);
        assert!(c.is_empty());
        assert_eq!(c.hit_rate(), 0.0);
    }

    #[test]
    fn reinsert_refreshes_value_in_place() {
        let mut c: PlanCache<u32> = PlanCache::new(2);
        c.insert(Sketch(1), &[1], 10);
        c.insert(Sketch(1), &[1], 11);
        assert_eq!(c.len(), 1);
        assert_eq!(c.lookup(Sketch(1), &[1]), Some(11));
    }

    #[test]
    fn restore_roundtrips_contents_and_lru_state() {
        let mut c: PlanCache<u32> = PlanCache::new(3);
        c.insert(Sketch(1), &[1], 10);
        c.insert(Sketch(2), &[2], 20);
        c.insert(Sketch(3), &[3], 30);
        assert_eq!(c.lookup(Sketch(1), &[1]), Some(10)); // 1 now freshest
        let dumped: Vec<(u64, Vec<u64>, u32, u64)> = c
            .entries()
            .map(|(s, k, v, t)| (s.0, k.to_vec(), *v, t))
            .collect();
        let mut r = PlanCache::restore(3, c.clock(), dumped.clone());
        assert_eq!(r.len(), 3);
        assert_eq!(r.hits, 0);
        assert_eq!(r.lookup(Sketch(2), &[2]), Some(20));
        // Shrunk capacity keeps the most recently used entries: 3 and
        // the freshly-touched 1 survive, 2 (stalest) is dropped.
        let mut small = PlanCache::restore(2, c.clock(), dumped.clone());
        assert_eq!(small.len(), 2);
        assert_eq!(small.lookup(Sketch(2), &[2]), None);
        assert_eq!(small.lookup(Sketch(1), &[1]), Some(10));
        assert_eq!(small.lookup(Sketch(3), &[3]), Some(30));
        // Capacity 0 restores a disabled cache.
        let zero = PlanCache::restore(0, c.clock(), dumped);
        assert!(zero.is_empty());
    }

    #[test]
    fn sketch_hash_is_stable_and_input_sensitive() {
        let a = Sketch::of(&[1, 2, 300], 4);
        assert_eq!(a, Sketch::of(&[1, 2, 300], 4));
        assert_ne!(a, Sketch::of(&[1, 2], 4));
        assert_ne!(a, Sketch::of(&[1, 2, 300, 300], 4));
    }
}
