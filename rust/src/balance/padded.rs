//! Algorithm 2: Post-Balancing with paddings (binary search + first-fit).
//!
//! With padded batching the batch length is `b * max(l)` (Eq. 1), so a
//! batch's cost is driven by its longest sequence. The paper's algorithm
//! sorts ascending, greedily packs consecutive runs under a candidate
//! bound `C` (`(count+1) * next_len > C` opens a new batch — `next_len`
//! is the running max because of the sort), and binary-searches the
//! smallest `C` for which at most `d` batches are needed. Complexity
//! O(n log(nC)).

use super::balancer::{Balancer, CostRegime};
use super::scratch::PlanScratch;
use super::types::{Assignment, BatchingMode, ExampleRef};

/// Pack ascending-sorted sequences first-fit under padded bound `c`;
/// returns batch boundaries (index ranges into `sorted`). Production
/// paths use the count-only / into-scratch variants below; this
/// allocating form remains as the test oracle.
#[cfg(test)]
fn least_batches(sorted: &[ExampleRef], c: usize) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    least_batches_into(sorted, c, &mut ranges);
    ranges
}

/// Allocation-free variant: write the boundaries into `ranges`.
fn least_batches_into(
    sorted: &[ExampleRef],
    c: usize,
    ranges: &mut Vec<(usize, usize)>,
) {
    ranges.clear();
    let mut start = 0;
    let mut count = 0usize;
    for (i, e) in sorted.iter().enumerate() {
        // Sorted ascending, so e.len is the padded length if e joins.
        if count > 0 && (count + 1) * e.len > c {
            ranges.push((start, i));
            start = i;
            count = 0;
        }
        count += 1;
    }
    if count > 0 {
        ranges.push((start, sorted.len()));
    }
}

/// Count-only packing for the binary search (no boundary bookkeeping).
fn batches_needed(sorted: &[ExampleRef], c: usize) -> usize {
    let mut batches = 0usize;
    let mut count = 0usize;
    for e in sorted {
        if count > 0 && (count + 1) * e.len > c {
            batches += 1;
            count = 0;
        }
        count += 1;
    }
    if count > 0 {
        batches += 1;
    }
    batches
}

/// Algorithm 2 of the paper, allocation-free given a warm scratch.
pub fn balance_padded_with(
    lens: &[usize],
    d: usize,
    scratch: &mut PlanScratch,
) -> Assignment {
    assert!(d > 0, "need at least one DP instance");
    let n = lens.len();
    if n == 0 {
        return vec![Vec::new(); d];
    }
    scratch.refs_asc(lens);

    let max_len = scratch.refs.last().unwrap().len;
    // Feasible range: a batch containing the longest sequence costs at
    // least max_len; (n/d + 1) sequences of max_len is always enough.
    let mut left = max_len;
    let mut right = max_len * (n / d + 1);
    while left < right {
        let mid = (left + right) / 2;
        if batches_needed(&scratch.refs, mid) <= d {
            right = mid;
        } else {
            left = mid + 1;
        }
    }
    least_batches_into(&scratch.refs, left, &mut scratch.ranges);
    let mut out: Assignment = Vec::with_capacity(d);
    for &(s, e) in &scratch.ranges {
        out.push(scratch.refs[s..e].to_vec());
    }
    // Fewer than d batches is legal (idle instances); pad with empties so
    // the assignment always has exactly d mini-batches.
    while out.len() < d {
        out.push(Vec::new());
    }
    out
}

/// Algorithm 2 of the paper (convenience wrapper over a fresh scratch).
pub fn balance_padded(lens: &[usize], d: usize) -> Assignment {
    balance_padded_with(lens, d, &mut PlanScratch::new())
}

/// Registry entry: `padded` (alias `alg2`).
#[derive(Clone, Copy, Debug)]
pub struct BinaryPadded;

impl Balancer for BinaryPadded {
    fn name(&self) -> &'static str {
        "padded"
    }

    fn batching_mode(&self) -> BatchingMode {
        BatchingMode::Padded
    }

    fn cost_regime(&self) -> CostRegime {
        CostRegime::Linear
    }

    fn balance(
        &self,
        lens: &[usize],
        d: usize,
        scratch: &mut PlanScratch,
    ) -> Assignment {
        balance_padded_with(lens, d, scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balance::types::{
        assert_valid_assignment, batch_length, makespan, BatchingMode,
        identity_with_lens,
    };
    use crate::util::prop::check;

    #[test]
    fn groups_similar_lengths_together() {
        // 4 short + 4 long over 2 instances: padding waste is minimized
        // when shorts share a batch and longs share a batch.
        let lens = vec![2, 2, 2, 2, 10, 10, 10, 10];
        let a = balance_padded(&lens, 2);
        assert_valid_assignment(&a, 8, 2);
        for batch in &a {
            if batch.is_empty() {
                continue;
            }
            let lmin = batch.iter().map(|e| e.len).min().unwrap();
            let lmax = batch.iter().map(|e| e.len).max().unwrap();
            assert_eq!(lmin, lmax, "mixed batch: {batch:?}");
        }
        assert_eq!(makespan(&a, BatchingMode::Padded), 40);
    }

    #[test]
    fn single_instance_gets_everything() {
        let a = balance_padded(&[1, 5, 3], 1);
        assert_valid_assignment(&a, 3, 1);
        assert_eq!(a[0].len(), 3);
    }

    #[test]
    fn empty_input() {
        let a = balance_padded(&[], 3);
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn uses_at_most_d_batches() {
        let lens: Vec<usize> = (1..=100).collect();
        let a = balance_padded(&lens, 7);
        assert_eq!(a.len(), 7);
        assert_valid_assignment(&a, 100, 7);
    }

    #[test]
    fn prop_valid_and_beats_identity() {
        check("padded valid + <= identity", 200, |g| {
            let d = g.usize(1, 10);
            let n = g.usize(d, d * 20);
            let lens = g.seq_lengths(n, 3.0, 1.3);
            let a = balance_padded(&lens, d);
            assert_valid_assignment(&a, n, d);
            let mb = makespan(&a, BatchingMode::Padded);
            let mi = makespan(
                &identity_with_lens(&lens, d),
                BatchingMode::Padded,
            );
            assert!(mb <= mi, "balanced {mb} > identity {mi}");
        });
    }

    #[test]
    fn prop_binary_search_is_tight() {
        // The chosen bound is minimal: every batch respects it, and the
        // packing at (bound - 1) would need more than d batches.
        check("padded tight", 100, |g| {
            let d = g.usize(1, 8);
            let n = g.usize(1, 80);
            let lens = g.seq_lengths(n, 2.5, 1.0);
            let a = balance_padded(&lens, d);
            let bound = a
                .iter()
                .map(|b| batch_length(b, BatchingMode::Padded))
                .max()
                .unwrap();
            // Re-deriving: no packing with a strictly smaller max batch
            // length can fit in d batches via the same first-fit scheme.
            let mut sorted: Vec<ExampleRef> = lens
                .iter()
                .enumerate()
                .map(|(id, &len)| ExampleRef { id, len })
                .collect();
            sorted.sort_unstable_by(|x, y| x.len.cmp(&y.len).then(x.id.cmp(&y.id)));
            if bound > 0 {
                assert!(
                    least_batches(&sorted, bound - 1).len() > d
                        || least_batches(&sorted, bound).len() <= d,
                    "bound not tight"
                );
            }
        });
    }

    #[test]
    fn prop_batches_are_length_runs() {
        // First-fit over an ascending sort yields contiguous length runs,
        // which is what minimizes padding waste.
        check("padded runs", 100, |g| {
            let d = g.usize(1, 6);
            let n = g.usize(1, 60);
            let lens = g.seq_lengths(n, 3.0, 1.0);
            let a = balance_padded(&lens, d);
            let mut prev_max = 0;
            for batch in a.iter().filter(|b| !b.is_empty()) {
                let lmin = batch.iter().map(|e| e.len).min().unwrap();
                let lmax = batch.iter().map(|e| e.len).max().unwrap();
                assert!(lmin >= prev_max, "batches overlap in length");
                prev_max = lmax;
            }
        });
    }
}
