//! Algorithm 1: Post-Balancing without paddings (LPT greedy).
//!
//! Sort sequences by length descending, keep the `d` new mini-batches in
//! a min-heap ordered by their current token sum, and always append to
//! the lightest batch. This is the classic Longest-Processing-Time rule,
//! a 4/3-approximation for the minimax makespan; complexity
//! O(n log n + n log d).

use super::balancer::{Balancer, CostRegime};
use super::scratch::{heap_assign, PlanScratch};
use super::types::{Assignment, BatchingMode};

/// Algorithm 1 of the paper, allocation-free given a warm scratch.
pub fn balance_lpt_with(
    lens: &[usize],
    d: usize,
    scratch: &mut PlanScratch,
) -> Assignment {
    assert!(d > 0, "need at least one DP instance");
    scratch.refs_desc(lens);
    scratch.heap_zeroed(d);
    let mut batches: Assignment = vec![Vec::new(); d];
    for &e in &scratch.refs {
        let i = heap_assign(&mut scratch.heap, e.len);
        batches[i].push(e);
    }
    batches
}

/// Algorithm 1 of the paper (convenience wrapper over a fresh scratch).
pub fn balance_lpt(lens: &[usize], d: usize) -> Assignment {
    balance_lpt_with(lens, d, &mut PlanScratch::new())
}

/// Registry entry: `greedy` (aliases `lpt`, `alg1`).
#[derive(Clone, Copy, Debug)]
pub struct GreedyLpt;

impl Balancer for GreedyLpt {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn batching_mode(&self) -> BatchingMode {
        BatchingMode::Unpadded
    }

    fn cost_regime(&self) -> CostRegime {
        CostRegime::Linear
    }

    fn balance(
        &self,
        lens: &[usize],
        d: usize,
        scratch: &mut PlanScratch,
    ) -> Assignment {
        balance_lpt_with(lens, d, scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balance::types::{
        assert_valid_assignment, batch_length, makespan, BatchingMode,
        identity_with_lens,
    };
    use crate::util::prop::check;

    #[test]
    fn simple_case_is_balanced() {
        // lens 8,7,6,5,4 over 2 instances: LPT gives makespan 17
        // (A={8,5,4}, B={7,6}); the optimum is 15, and 17 <= 4/3 * 15.
        let a = balance_lpt(&[8, 7, 6, 5, 4], 2);
        assert_valid_assignment(&a, 5, 2);
        assert!(makespan(&a, BatchingMode::Unpadded) <= 20);
    }

    #[test]
    fn empty_input_yields_empty_batches() {
        let a = balance_lpt(&[], 4);
        assert_eq!(a.len(), 4);
        assert!(a.iter().all(|b| b.is_empty()));
    }

    #[test]
    fn fewer_examples_than_instances() {
        let a = balance_lpt(&[10, 20], 5);
        assert_valid_assignment(&a, 2, 5);
        assert_eq!(makespan(&a, BatchingMode::Unpadded), 20);
    }

    #[test]
    fn deterministic() {
        let lens = vec![5, 9, 1, 7, 7, 3, 2, 8];
        assert_eq!(balance_lpt(&lens, 3), balance_lpt(&lens, 3));
    }

    #[test]
    fn scratch_reuse_matches_fresh_allocation() {
        let mut s = PlanScratch::new();
        let mut g = crate::util::prop::Gen::new(77);
        for _ in 0..20 {
            let d = g.usize(1, 9);
            let lens = g.seq_lengths(g.usize(0, 120), 3.0, 1.1);
            assert_eq!(
                balance_lpt_with(&lens, d, &mut s),
                balance_lpt(&lens, d),
            );
        }
    }

    #[test]
    fn prop_valid_and_within_lpt_bound() {
        // LPT guarantee: makespan <= 4/3 * OPT, and OPT >= max(total/d,
        // max_len), so makespan <= 4/3 * max(ceil(total/d), max_len) + 1.
        check("lpt bound", 200, |g| {
            let d = g.usize(1, 12);
            let n = g.usize(0, 120);
            let lens = g.seq_lengths(n, 3.0, 1.2);
            let a = balance_lpt(&lens, d);
            assert_valid_assignment(&a, n, d);
            if n == 0 {
                return;
            }
            let total: usize = lens.iter().sum();
            let max_len = *lens.iter().max().unwrap();
            let lower =
                ((total + d - 1) / d).max(max_len) as f64;
            let got = makespan(&a, BatchingMode::Unpadded) as f64;
            assert!(
                got <= lower * 4.0 / 3.0 + 1.0,
                "makespan {got} exceeds 4/3 bound of lower {lower}"
            );
        });
    }

    #[test]
    fn prop_never_worse_than_identity() {
        check("lpt <= identity", 200, |g| {
            let d = g.usize(1, 8);
            let n = g.usize(d, d * 16);
            let lens = g.seq_lengths(n, 3.5, 1.0);
            let balanced = balance_lpt(&lens, d);
            let identity = identity_with_lens(&lens, d);
            let mb = makespan(&balanced, BatchingMode::Unpadded);
            let mi = makespan(&identity, BatchingMode::Unpadded);
            assert!(mb <= mi, "balanced {mb} > identity {mi}");
        });
    }

    #[test]
    fn prop_batch_sums_tight() {
        // With many small sequences the spread between the heaviest and
        // lightest batch should be at most the largest sequence length.
        check("lpt spread", 100, |g| {
            let d = g.usize(2, 8);
            let lens = g.seq_lengths(d * 20, 3.0, 0.8);
            let a = balance_lpt(&lens, d);
            let sums: Vec<usize> = a
                .iter()
                .map(|b| batch_length(b, BatchingMode::Unpadded))
                .collect();
            let spread =
                sums.iter().max().unwrap() - sums.iter().min().unwrap();
            let max_len = *lens.iter().max().unwrap();
            assert!(
                spread <= max_len,
                "spread {spread} > max_len {max_len}"
            );
        });
    }
}
