//! The per-phase computational-cost functions of paper Eq. (2).
//!
//! `f(S_i)` estimates the compute (and, proportionally, activation
//! memory) a mini-batch costs on one DP instance. The α term is the
//! token-linear work (MLPs, projections); the β term the attention
//! quadratic. The balancing algorithms minimize `max_i f(S'_i)`; the
//! cluster simulator prices phases with the same functions, which is
//! what keeps the benchmarked logic identical to the shipped logic.

use super::types::{batch_length, BatchingMode, ExampleRef};

/// The cost regime of a phase (Eq. 2 and Appendix A variants).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CostModel {
    /// β ≪ α: cost ≈ α·L (both batching modes).
    Linear { alpha: f64 },
    /// No padding, full Eq. 2: α·L + β·Σ l².
    TransformerUnpadded { alpha: f64, beta: f64 },
    /// Padded, full Eq. 2: α·L + (β/b)·L², with L = b·max(l).
    TransformerPadded { alpha: f64, beta: f64 },
    /// ConvTransformer (App. A): α·L + λ·b·max(l)² — attention must pad.
    ConvPadded { alpha: f64, lambda: f64 },
}

impl CostModel {
    /// Evaluate `f(S)` for one mini-batch.
    pub fn eval(&self, batch: &[ExampleRef]) -> f64 {
        if batch.is_empty() {
            return 0.0;
        }
        let b = batch.len() as f64;
        let max_l = batch.iter().map(|e| e.len).max().unwrap_or(0) as f64;
        match *self {
            CostModel::Linear { alpha } => {
                let l = batch_length(batch, self.mode()) as f64;
                alpha * l
            }
            CostModel::TransformerUnpadded { alpha, beta } => {
                let l = batch_length(batch, BatchingMode::Unpadded) as f64;
                let sq: f64 =
                    batch.iter().map(|e| (e.len * e.len) as f64).sum();
                alpha * l + beta * sq
            }
            CostModel::TransformerPadded { alpha, beta } => {
                let l = b * max_l;
                alpha * l + beta * l * l / b
            }
            CostModel::ConvPadded { alpha, lambda } => {
                let l = b * max_l;
                alpha * l + lambda * b * max_l * max_l
            }
        }
    }

    /// The batching mode this regime implies.
    pub fn mode(&self) -> BatchingMode {
        match self {
            CostModel::Linear { .. } | CostModel::TransformerUnpadded { .. } => {
                BatchingMode::Unpadded
            }
            CostModel::TransformerPadded { .. }
            | CostModel::ConvPadded { .. } => BatchingMode::Padded,
        }
    }

    /// Minimax objective over an assignment.
    pub fn makespan(&self, assignment: &[Vec<ExampleRef>]) -> f64 {
        assignment
            .iter()
            .map(|b| self.eval(b))
            .fold(0.0, f64::max)
    }

    /// Balance ratio: max cost / mean cost (1.0 = perfectly balanced).
    pub fn imbalance(&self, assignment: &[Vec<ExampleRef>]) -> f64 {
        let costs: Vec<f64> =
            assignment.iter().map(|b| self.eval(b)).collect();
        let max = costs.iter().copied().fold(0.0, f64::max);
        let mean = costs.iter().sum::<f64>() / costs.len().max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

/// A phase's full cost description: the Eq.-2 regime plus the per-token
/// FLOP weight used by the simulator to convert cost into seconds.
#[derive(Clone, Copy, Debug)]
pub struct PhaseCost {
    pub model: CostModel,
    /// FLOPs per unit of `CostModel::eval` output.
    pub flops_per_unit: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balance::types::make_refs;

    #[test]
    fn linear_cost_is_alpha_times_length() {
        let b = make_refs(&[3, 5]);
        let m = CostModel::Linear { alpha: 2.0 };
        assert_eq!(m.eval(&b), 16.0); // unpadded: 2 * (3+5)
    }

    #[test]
    fn unpadded_quadratic_adds_sq_term() {
        let b = make_refs(&[3, 5]);
        let m = CostModel::TransformerUnpadded { alpha: 1.0, beta: 0.1 };
        let want = 8.0 + 0.1 * (9.0 + 25.0);
        assert!((m.eval(&b) - want).abs() < 1e-12);
    }

    #[test]
    fn padded_quadratic_uses_max_len() {
        let b = make_refs(&[3, 5]);
        let m = CostModel::TransformerPadded { alpha: 1.0, beta: 0.1 };
        // L = 2*5 = 10; f = 10 + 0.1*100/2 = 15
        assert!((m.eval(&b) - 15.0).abs() < 1e-12);
    }

    #[test]
    fn conv_padded_matches_appendix_form() {
        let b = make_refs(&[3, 5]);
        let m = CostModel::ConvPadded { alpha: 1.0, lambda: 0.01 };
        // L = 10; + 0.01 * 2 * 25 = 0.5
        assert!((m.eval(&b) - 10.5).abs() < 1e-12);
    }

    #[test]
    fn empty_batch_costs_zero() {
        for m in [
            CostModel::Linear { alpha: 1.0 },
            CostModel::TransformerUnpadded { alpha: 1.0, beta: 1.0 },
            CostModel::TransformerPadded { alpha: 1.0, beta: 1.0 },
            CostModel::ConvPadded { alpha: 1.0, lambda: 1.0 },
        ] {
            assert_eq!(m.eval(&[]), 0.0);
        }
    }

    #[test]
    fn imbalance_of_equal_batches_is_one() {
        let a = vec![make_refs(&[4, 4]), make_refs(&[4, 4])];
        let m = CostModel::Linear { alpha: 1.0 };
        assert!((m.imbalance(&a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_grows_with_skew() {
        let skewed = vec![make_refs(&[16]), make_refs(&[1])];
        let m = CostModel::Linear { alpha: 1.0 };
        assert!(m.imbalance(&skewed) > 1.5);
    }
}
