//! Pre-Balancing baselines (paper §3.2).
//!
//! These operate at *sampling time*, before mini-batches are fixed —
//! exactly the class of methods the paper argues cannot solve the
//! multi-objective problem Modality Composition Incoherence creates.
//! They are implemented faithfully so Fig. 10's comparison (and the
//! "w/o balance" baseline of Fig. 8/9) can be regenerated:
//!
//! * [`fixed_batch`] — classic DP: every instance samples `b` examples.
//! * [`dynamic_token_bound`] — replace the fixed batch size with a token
//!   budget per mini-batch (the "dynamic batch size" method).
//! * [`bucketed`] — accumulate examples into length buckets and emit a
//!   batch when a bucket fills (better balance, weaker randomness).
//! * [`fixed_llm_length`] — the DistTrain-style method: pick examples so
//!   every mini-batch hits (approximately) the same LLM-phase token
//!   count, balancing only that single phase.

use crate::util::rng::Pcg64;

/// A sampled example, as the pre-balancers see it: per-phase lengths.
#[derive(Clone, Copy, Debug)]
pub struct ExampleLens {
    pub id: usize,
    /// LLM-phase (interleaved sequence) length.
    pub llm: usize,
    /// Vision metadata length (0 when absent).
    pub vision: usize,
    /// Audio metadata length (0 when absent).
    pub audio: usize,
}

/// Classic DP sampling: shuffle, then deal fixed-size mini-batches.
pub fn fixed_batch(
    examples: &[ExampleLens],
    d: usize,
    batch_size: usize,
    rng: &mut Pcg64,
) -> Vec<Vec<ExampleLens>> {
    let mut pool: Vec<ExampleLens> = examples.to_vec();
    rng.shuffle(&mut pool);
    (0..d)
        .map(|i| {
            pool.iter()
                .skip(i * batch_size)
                .take(batch_size)
                .copied()
                .collect()
        })
        .collect()
}

/// Dynamic batch size: each instance keeps pulling from its shard until
/// the LLM token budget is exceeded.
pub fn dynamic_token_bound(
    examples: &[ExampleLens],
    d: usize,
    token_budget: usize,
    rng: &mut Pcg64,
) -> Vec<Vec<ExampleLens>> {
    let mut pool: Vec<ExampleLens> = examples.to_vec();
    rng.shuffle(&mut pool);
    let shard = pool.len() / d.max(1);
    (0..d)
        .map(|i| {
            let mut batch = Vec::new();
            let mut tokens = 0;
            for e in pool.iter().skip(i * shard).take(shard) {
                if tokens + e.llm > token_budget && !batch.is_empty() {
                    break;
                }
                tokens += e.llm;
                batch.push(*e);
            }
            batch
        })
        .collect()
}

/// Bucketed batching: route examples into `buckets` length ranges; a
/// bucket emits a batch once it holds `batch_size` examples. Returns the
/// first `d` emitted batches (one per instance).
pub fn bucketed(
    examples: &[ExampleLens],
    d: usize,
    batch_size: usize,
    bucket_bounds: &[usize],
    rng: &mut Pcg64,
) -> Vec<Vec<ExampleLens>> {
    let mut pool: Vec<ExampleLens> = examples.to_vec();
    rng.shuffle(&mut pool);
    let mut buckets: Vec<Vec<ExampleLens>> =
        vec![Vec::new(); bucket_bounds.len() + 1];
    let mut out = Vec::new();
    for e in pool {
        let idx = bucket_bounds
            .iter()
            .position(|&b| e.llm <= b)
            .unwrap_or(bucket_bounds.len());
        buckets[idx].push(e);
        if buckets[idx].len() == batch_size {
            out.push(std::mem::take(&mut buckets[idx]));
            if out.len() == d {
                return out;
            }
        }
    }
    // Flush partially-filled buckets if the stream ran dry.
    for b in buckets.into_iter().filter(|b| !b.is_empty()) {
        if out.len() == d {
            break;
        }
        out.push(b);
    }
    while out.len() < d {
        out.push(Vec::new());
    }
    out
}

/// DistTrain-style pre-balancing: target an (approximately) fixed LLM
/// token count per mini-batch by greedy best-fit from a shuffled pool.
/// Balances the LLM phase only — encoder-phase imbalance is whatever the
/// modality composition of the chosen examples happens to be.
pub fn fixed_llm_length(
    examples: &[ExampleLens],
    d: usize,
    llm_tokens_target: usize,
    rng: &mut Pcg64,
) -> Vec<Vec<ExampleLens>> {
    let mut pool: Vec<ExampleLens> = examples.to_vec();
    rng.shuffle(&mut pool);
    let mut batches: Vec<Vec<ExampleLens>> = vec![Vec::new(); d];
    let mut totals = vec![0usize; d];
    // Deal longest-first into the emptiest batch that still has budget —
    // the greedy DistTrain §4 describes for its image rebalancing, here
    // applied to the LLM phase.
    pool.sort_unstable_by(|a, b| b.llm.cmp(&a.llm));
    for e in pool {
        let (i, _) = totals
            .iter()
            .enumerate()
            .min_by_key(|(_, &t)| t)
            .unwrap();
        if totals[i] + e.llm > llm_tokens_target && !batches[i].is_empty() {
            continue; // budget exhausted everywhere that matters
        }
        totals[i] += e.llm;
        batches[i].push(e);
    }
    batches
}

/// Registry entry `prebalance-fixed`: the classic-DP sampling baseline
/// expressed as a post-hoc [`Balancer`] so Fig.-10-style comparisons
/// run through the same dispatcher path as the real algorithms. Shuffle
/// deterministically (every replica derives the same permutation from
/// the input shape — no extra communication), then deal equal-count
/// mini-batches: batch *sizes* are balanced, token loads are whatever
/// the draw happens to be.
///
/// NOTE: like every registered balancer, the registry wraps these
/// baselines in `Guarded`, which falls back to the identity dealing on
/// draws where the shuffle/bucketing regresses past it — the registry
/// invariant (never worse than `NoBalance`) takes precedence over
/// baseline fidelity. For faithful §3.2 baseline measurements use the
/// raw sampling-time functions in this module ([`fixed_batch`],
/// [`bucketed`], …), which are what the Fig.-10 experiments call.
#[derive(Clone, Copy, Debug)]
pub struct FixedBatchPrebalance;

impl crate::balance::balancer::Balancer for FixedBatchPrebalance {
    fn name(&self) -> &'static str {
        "prebalance-fixed"
    }

    fn batching_mode(&self) -> crate::balance::types::BatchingMode {
        crate::balance::types::BatchingMode::Unpadded
    }

    fn cost_regime(&self) -> crate::balance::balancer::CostRegime {
        crate::balance::balancer::CostRegime::Linear
    }

    fn balance(
        &self,
        lens: &[usize],
        d: usize,
        _scratch: &mut crate::balance::scratch::PlanScratch,
    ) -> crate::balance::types::Assignment {
        use crate::balance::types::ExampleRef;
        assert!(d > 0, "need at least one DP instance");
        let mut ids: Vec<usize> = (0..lens.len()).collect();
        let mut rng = Pcg64::new(0x5A3B_1E5D ^ lens.len() as u64);
        rng.shuffle(&mut ids);
        let mut out: crate::balance::types::Assignment =
            vec![Vec::new(); d];
        for (k, &id) in ids.iter().enumerate() {
            out[k % d].push(ExampleRef { id, len: lens[id] });
        }
        out
    }
}

/// Registry entry `prebalance-bucketed`: the length-bucketing baseline
/// as a post-hoc [`Balancer`] — sort by length and deal contiguous
/// runs, so each mini-batch holds similar lengths (minimal padding
/// waste) at the price of concentrating the long tail on one instance.
#[derive(Clone, Copy, Debug)]
pub struct BucketedPrebalance;

impl crate::balance::balancer::Balancer for BucketedPrebalance {
    fn name(&self) -> &'static str {
        "prebalance-bucketed"
    }

    fn batching_mode(&self) -> crate::balance::types::BatchingMode {
        crate::balance::types::BatchingMode::Padded
    }

    fn cost_regime(&self) -> crate::balance::balancer::CostRegime {
        crate::balance::balancer::CostRegime::Linear
    }

    fn balance(
        &self,
        lens: &[usize],
        d: usize,
        scratch: &mut crate::balance::scratch::PlanScratch,
    ) -> crate::balance::types::Assignment {
        assert!(d > 0, "need at least one DP instance");
        scratch.refs_asc(lens);
        let n = lens.len();
        let base = n / d;
        let extra = n % d;
        let mut out: crate::balance::types::Assignment =
            Vec::with_capacity(d);
        let mut start = 0;
        for i in 0..d {
            let b = base + usize::from(i < extra);
            out.push(scratch.refs[start..start + b].to_vec());
            start += b;
        }
        out
    }
}

/// Per-phase token sums of pre-balanced batches (for imbalance metrics).
pub fn phase_sums(batches: &[Vec<ExampleLens>]) -> (Vec<usize>, Vec<usize>, Vec<usize>) {
    let llm = batches
        .iter()
        .map(|b| b.iter().map(|e| e.llm).sum())
        .collect();
    let vis = batches
        .iter()
        .map(|b| b.iter().map(|e| e.vision).sum())
        .collect();
    let aud = batches
        .iter()
        .map(|b| b.iter().map(|e| e.audio).sum())
        .collect();
    (llm, vis, aud)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    fn synth(n: usize, seed: u64) -> Vec<ExampleLens> {
        // Incoherent mixture: ASR-like (audio-heavy), caption-like
        // (vision-heavy), text-only.
        let mut rng = Pcg64::new(seed);
        (0..n)
            .map(|id| {
                let task = rng.weighted(&[1.0, 1.0, 1.0]);
                let (v, a) = match task {
                    0 => (0, rng.range(50, 400)),
                    1 => (rng.range(64, 512), 0),
                    _ => (0, 0),
                };
                let text = rng.range(10, 200);
                ExampleLens { id, llm: text + v / 2 + a / 2, vision: v, audio: a }
            })
            .collect()
    }

    #[test]
    fn fixed_batch_deals_exact_sizes() {
        let ex = synth(100, 1);
        let mut rng = Pcg64::new(2);
        let b = fixed_batch(&ex, 4, 10, &mut rng);
        assert_eq!(b.len(), 4);
        assert!(b.iter().all(|x| x.len() == 10));
    }

    #[test]
    fn dynamic_bound_respects_budget() {
        let ex = synth(400, 3);
        let mut rng = Pcg64::new(4);
        let b = dynamic_token_bound(&ex, 4, 800, &mut rng);
        for batch in &b {
            let toks: usize = batch.iter().map(|e| e.llm).sum();
            // A single over-budget example is allowed (it must go
            // somewhere), otherwise the budget holds.
            assert!(toks <= 800 || batch.len() == 1, "tokens {toks}");
        }
    }

    #[test]
    fn dynamic_bound_balances_llm_better_than_fixed() {
        let ex = synth(2000, 5);
        let mut r1 = Pcg64::new(6);
        let mut r2 = Pcg64::new(6);
        let fixed = fixed_batch(&ex, 8, 30, &mut r1);
        let dynamic = dynamic_token_bound(&ex, 8, 6000, &mut r2);
        let cv = |b: &[Vec<ExampleLens>]| {
            let (llm, _, _) = phase_sums(b);
            Summary::from_slice(
                &llm.iter().map(|&x| x as f64).collect::<Vec<_>>(),
            )
            .cv()
        };
        assert!(cv(&dynamic) < cv(&fixed), "{} vs {}", cv(&dynamic), cv(&fixed));
    }

    #[test]
    fn fixed_llm_length_balances_llm_not_encoders() {
        // The core §3.1 claim: balancing the LLM phase leaves encoder
        // phases imbalanced under Modality Composition Incoherence.
        let ex = synth(4000, 7);
        let mut rng = Pcg64::new(8);
        let b = fixed_llm_length(&ex, 8, 4000, &mut rng);
        let (llm, vis, aud) = phase_sums(&b);
        let cv = |xs: &[usize]| {
            Summary::from_slice(
                &xs.iter().map(|&x| x as f64).collect::<Vec<_>>(),
            )
            .cv()
        };
        assert!(cv(&llm) < 0.05, "llm cv {}", cv(&llm));
        assert!(
            cv(&vis) > 2.0 * cv(&llm) || cv(&aud) > 2.0 * cv(&llm),
            "encoders unexpectedly balanced: vis {} aud {} llm {}",
            cv(&vis),
            cv(&aud),
            cv(&llm)
        );
    }

    #[test]
    fn bucketed_groups_similar_lengths() {
        let ex = synth(3000, 9);
        let mut rng = Pcg64::new(10);
        let b = bucketed(&ex, 6, 20, &[100, 200, 400], &mut rng);
        assert_eq!(b.len(), 6);
        for batch in b.iter().filter(|b| b.len() > 1) {
            let lo = batch.iter().map(|e| e.llm).min().unwrap();
            let hi = batch.iter().map(|e| e.llm).max().unwrap();
            // Same bucket => both under the same bound.
            let bucket_of = |l: usize| {
                [100usize, 200, 400]
                    .iter()
                    .position(|&x| l <= x)
                    .unwrap_or(3)
            };
            assert_eq!(bucket_of(lo), bucket_of(hi));
        }
    }
}
