//! Approximation-gap harness: every heuristic vs the exact oracle.
//!
//! With [`super::ilp`] certifying optima on small instances, every
//! registered heuristic's distance from the true minimax optimum is
//! measurable instead of assumed. The harness sweeps a grid of
//! **modality-incoherence profiles** — length distributions spanning
//! the near-uniform to pathologically-skewed batches §2.3/§3 describe —
//! and reports, per `(heuristic, profile)`:
//!
//! ```text
//! gap = makespan(heuristic) / makespan(oracle) − 1
//! ```
//!
//! under the heuristic's *own* cost model, counted only on cases the
//! oracle certified ([`IlpStatus::Optimal`]) so every gap is against a
//! true optimum, never a best-effort incumbent. Certified gaps are
//! nonnegative by construction — a negative gap would mean the "exact"
//! solver lost to a heuristic and is asserted against.
//!
//! `benches/balancer_gaps.rs` drives this, emits
//! `BENCH_balancer_gaps.json`, and gates CI against the checked-in
//! ceilings in `ci/gap_baseline.json` ([`GapReport::check_baseline`]);
//! `sim::report::render_balancer_gaps` renders the table.

use crate::util::json::Json;
use crate::util::rng::Pcg64;

use super::balancer::registry;
use super::ilp::{self, IlpStatus};
use super::scratch::PlanScratch;

/// The heuristics the gap suite measures (everything registered except
/// the identity, the oracle itself, and the sampling-time baselines).
pub const GAP_HEURISTICS: &[&str] =
    &["greedy", "kk", "padded", "quadratic", "convpad"];

/// A modality-incoherence profile: how one phase's active lengths are
/// distributed across a mini-batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProfileKind {
    /// Mild incoherence: tight log-normal around the median length.
    NearUniform,
    /// Production shape (§2.3): heavy-tailed log-normal.
    HeavyTail,
    /// One giant sequence among tiny ones — the padded-batching and
    /// greedy-commitment worst case.
    OneGiant,
    /// Task-mixture bimodality: text-only-like short sequences mixed
    /// with vision/audio-heavy long ones (Fig. 3's two extremes).
    Bimodal,
}

/// A named profile in the sweep grid.
#[derive(Clone, Copy, Debug)]
pub struct GapProfile {
    pub name: &'static str,
    pub kind: ProfileKind,
}

/// The default grid: ≥ 4 incoherence profiles, mildest to harshest.
pub const PROFILES: &[GapProfile] = &[
    GapProfile { name: "near-uniform", kind: ProfileKind::NearUniform },
    GapProfile { name: "heavy-tail", kind: ProfileKind::HeavyTail },
    GapProfile { name: "one-giant", kind: ProfileKind::OneGiant },
    GapProfile { name: "bimodal", kind: ProfileKind::Bimodal },
];

impl GapProfile {
    /// Sample one batch's active lengths.
    pub fn lengths(&self, rng: &mut Pcg64, n: usize) -> Vec<usize> {
        match self.kind {
            ProfileKind::NearUniform => (0..n)
                .map(|_| {
                    (rng.lognormal(4.0, 0.2).round() as usize).max(1)
                })
                .collect(),
            ProfileKind::HeavyTail => (0..n)
                .map(|_| {
                    (rng.lognormal(3.2, 1.4).round() as usize).max(1)
                })
                .collect(),
            ProfileKind::OneGiant => {
                let mut lens: Vec<usize> =
                    (0..n).map(|_| rng.range(2, 16)).collect();
                let giant = rng.range(0, n.max(1));
                lens[giant] = rng.range(2_000, 8_000);
                lens
            }
            ProfileKind::Bimodal => (0..n)
                .map(|_| {
                    let (mu, sigma) = if rng.bool(0.5) {
                        (2.5, 0.4) // text-only-like
                    } else {
                        (5.5, 0.5) // vision/audio-heavy
                    };
                    (rng.lognormal(mu, sigma).round() as usize).max(1)
                })
                .collect(),
        }
    }
}

/// Sweep configuration: instance sizes are kept small enough for the
/// oracle to certify within the node budget.
#[derive(Clone, Copy, Debug)]
pub struct GapConfig {
    /// Cases per `(profile, size)` cell.
    pub cases_per_cell: usize,
    /// `(n, d)` instance sizes.
    pub sizes: &'static [(usize, usize)],
    /// Oracle node budget per solve.
    pub node_budget: usize,
    pub seed: u64,
}

impl GapConfig {
    /// The CI smoke grid (also what `ci/gap_baseline.json` gates).
    pub fn smoke() -> GapConfig {
        GapConfig {
            cases_per_cell: 6,
            sizes: &[(10, 2), (12, 3), (14, 4), (16, 4)],
            node_budget: 200_000,
            seed: 42,
        }
    }

    /// The full grid (local runs, larger instances).
    pub fn full() -> GapConfig {
        GapConfig {
            cases_per_cell: 12,
            sizes: &[(12, 3), (16, 4), (20, 5), (24, 6)],
            node_budget: 1_000_000,
            seed: 42,
        }
    }

    /// A minimal grid for unit tests.
    pub fn tiny() -> GapConfig {
        GapConfig {
            cases_per_cell: 2,
            sizes: &[(8, 2), (10, 3)],
            node_budget: 50_000,
            seed: 7,
        }
    }
}

/// Aggregate gaps of one heuristic on one profile.
#[derive(Clone, Debug)]
pub struct GapRow {
    pub heuristic: String,
    pub profile: String,
    pub cases: usize,
    /// Cases the oracle certified (gaps are measured on these only).
    pub certified: usize,
    pub mean_gap: f64,
    pub max_gap: f64,
    pub mean_oracle_nodes: f64,
}

/// The full sweep result.
#[derive(Clone, Debug)]
pub struct GapReport {
    pub rows: Vec<GapRow>,
    pub node_budget: usize,
    pub seed: u64,
}

impl GapReport {
    /// Max gap of one heuristic across every profile (certified cases).
    pub fn overall_max_gap(&self, heuristic: &str) -> f64 {
        self.rows
            .iter()
            .filter(|r| r.heuristic == heuristic && r.certified > 0)
            .map(|r| r.max_gap)
            .fold(0.0, f64::max)
    }

    /// Mean gap of one heuristic across every certified case.
    pub fn overall_mean_gap(&self, heuristic: &str) -> f64 {
        let (mut sum, mut count) = (0.0f64, 0usize);
        for r in &self.rows {
            if r.heuristic == heuristic {
                sum += r.mean_gap * r.certified as f64;
                count += r.certified;
            }
        }
        if count == 0 {
            0.0
        } else {
            sum / count as f64
        }
    }

    /// Fraction of all `(heuristic, case)` solves the oracle certified.
    pub fn certified_fraction(&self) -> f64 {
        let cases: usize = self.rows.iter().map(|r| r.cases).sum();
        let certified: usize =
            self.rows.iter().map(|r| r.certified).sum();
        if cases == 0 {
            0.0
        } else {
            certified as f64 / cases as f64
        }
    }

    /// Certified fraction for one heuristic. The gate checks this per
    /// heuristic, not just in aggregate: a cost model the oracle stops
    /// certifying would otherwise make its heuristic's gap read as a
    /// vacuous 0.0 while the aggregate fraction still passes.
    pub fn certified_fraction_of(&self, heuristic: &str) -> f64 {
        let (mut cases, mut certified) = (0usize, 0usize);
        for r in &self.rows {
            if r.heuristic == heuristic {
                cases += r.cases;
                certified += r.certified;
            }
        }
        if cases == 0 {
            0.0
        } else {
            certified as f64 / cases as f64
        }
    }

    /// Serialize for `BENCH_balancer_gaps.json`.
    pub fn to_json(&self) -> Json {
        let rows = Json::arr(self.rows.iter().map(|r| {
            Json::obj(vec![
                ("heuristic", Json::str(&r.heuristic)),
                ("profile", Json::str(&r.profile)),
                ("cases", Json::num(r.cases as f64)),
                ("certified", Json::num(r.certified as f64)),
                ("mean_gap", Json::num(r.mean_gap)),
                ("max_gap", Json::num(r.max_gap)),
                ("mean_oracle_nodes", Json::num(r.mean_oracle_nodes)),
            ])
        }));
        let overall = Json::obj(
            GAP_HEURISTICS
                .iter()
                .map(|&h| {
                    (
                        h,
                        Json::obj(vec![
                            (
                                "max_gap",
                                Json::num(self.overall_max_gap(h)),
                            ),
                            (
                                "mean_gap",
                                Json::num(self.overall_mean_gap(h)),
                            ),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("bench", Json::str("balancer_gaps")),
            ("node_budget", Json::num(self.node_budget as f64)),
            ("seed", Json::num(self.seed as f64)),
            (
                "certified_fraction",
                Json::num(self.certified_fraction()),
            ),
            ("rows", rows),
            ("overall", overall),
        ])
    }

    /// Gate against a checked-in baseline (`ci/gap_baseline.json`):
    ///
    /// ```json
    /// { "slack": 0.02, "max_gap": { "greedy": 0.34, ... } }
    /// ```
    ///
    /// Returns one message per regression — a heuristic whose measured
    /// overall max gap exceeds its ceiling plus the slack (which
    /// absorbs cross-platform libm ULP noise in the generated lengths),
    /// a heuristic the oracle certified nothing for (its gap would be a
    /// vacuous 0.0), or a measured heuristic the baseline does not
    /// cover. Empty = pass.
    pub fn check_baseline(&self, baseline: &Json) -> Vec<String> {
        let slack = baseline.get("slack").as_f64().unwrap_or(0.0);
        let ceilings = baseline.get("max_gap");
        let mut regressions = Vec::new();
        for &h in GAP_HEURISTICS {
            if self.certified_fraction_of(h) == 0.0 {
                regressions.push(format!(
                    "{h}: oracle certified no cases — gap unmeasured, \
                     gate cannot pass vacuously"
                ));
                continue;
            }
            let measured = self.overall_max_gap(h);
            match ceilings.get(h).as_f64() {
                Some(ceiling) => {
                    if measured > ceiling + slack {
                        regressions.push(format!(
                            "{h}: max gap {measured:.4} exceeds \
                             baseline {ceiling:.4} (+{slack:.4} slack)"
                        ));
                    }
                }
                None => regressions.push(format!(
                    "{h}: no baseline entry in ci/gap_baseline.json"
                )),
            }
        }
        regressions
    }
}

/// Run the sweep: every heuristic in [`GAP_HEURISTICS`] against the
/// oracle on every `(profile, size, case)` cell. Deterministic in
/// `cfg.seed` — each cell draws from its own forked stream, so cells
/// are independent of sweep order.
pub fn run_gap_suite(cfg: &GapConfig) -> GapReport {
    let mut scratch = PlanScratch::new();
    let balancers: Vec<_> = GAP_HEURISTICS
        .iter()
        .map(|&h| {
            let b = registry::must(h);
            let cm = b.cost_model();
            (b, cm)
        })
        .collect();
    #[derive(Default)]
    struct Acc {
        cases: usize,
        certified: usize,
        gap_sum: f64,
        gap_max: f64,
        nodes_sum: f64,
    }
    let mut rows = Vec::new();
    for profile in PROFILES {
        let mut accs: Vec<Acc> = (0..balancers.len())
            .map(|_| Acc::default())
            .collect();
        // One stream per (profile, size, case): deterministic cells,
        // shared by every heuristic so comparisons are like-for-like.
        let mut root = Pcg64::new(cfg.seed);
        for (si, &(n, d)) in cfg.sizes.iter().enumerate() {
            for case in 0..cfg.cases_per_cell {
                let mut rng = root.fork((si * 1_000 + case) as u64);
                let lens = profile.lengths(&mut rng, n);
                // Heuristics sharing a cost model (greedy and kk are
                // both Linear) share one oracle solve per cell.
                let mut oracle_cache: Vec<(
                    crate::balance::cost::CostModel,
                    crate::balance::ilp::IlpSolution,
                )> = Vec::new();
                for ((b, cm), acc) in balancers.iter().zip(&mut accs) {
                    acc.cases += 1;
                    let heur = b.balance(&lens, d, &mut scratch);
                    let oracle = match oracle_cache
                        .iter()
                        .find(|(c, _)| c == cm)
                    {
                        Some((_, s)) => s.clone(),
                        None => {
                            let s = ilp::solve_with(
                                cm,
                                &lens,
                                d,
                                cfg.node_budget,
                                &mut scratch,
                            );
                            oracle_cache.push((*cm, s.clone()));
                            s
                        }
                    };
                    if oracle.status != IlpStatus::Optimal
                        || oracle.makespan <= 0.0
                    {
                        continue;
                    }
                    let gap =
                        cm.makespan(&heur) / oracle.makespan - 1.0;
                    assert!(
                        gap >= -1e-9,
                        "{}: heuristic beat a certified optimum \
                         (gap {gap})",
                        b.name()
                    );
                    let gap = gap.max(0.0);
                    acc.certified += 1;
                    acc.gap_sum += gap;
                    acc.gap_max = acc.gap_max.max(gap);
                    acc.nodes_sum += oracle.nodes as f64;
                }
            }
        }
        for (&h, acc) in GAP_HEURISTICS.iter().zip(&accs) {
            rows.push(GapRow {
                heuristic: h.to_string(),
                profile: profile.name.to_string(),
                cases: acc.cases,
                certified: acc.certified,
                mean_gap: if acc.certified == 0 {
                    0.0
                } else {
                    acc.gap_sum / acc.certified as f64
                },
                max_gap: acc.gap_max,
                mean_oracle_nodes: if acc.certified == 0 {
                    0.0
                } else {
                    acc.nodes_sum / acc.certified as f64
                },
            });
        }
    }
    GapReport { rows, node_budget: cfg.node_budget, seed: cfg.seed }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_suite_certifies_and_reports_nonnegative_gaps() {
        let report = run_gap_suite(&GapConfig::tiny());
        assert_eq!(
            report.rows.len(),
            PROFILES.len() * GAP_HEURISTICS.len()
        );
        assert!(
            report.certified_fraction() > 0.8,
            "oracle certified only {:.0}% of tiny instances",
            report.certified_fraction() * 100.0
        );
        for r in &report.rows {
            assert!(r.max_gap >= r.mean_gap - 1e-12, "{r:?}");
            assert!(r.certified <= r.cases, "{r:?}");
        }
    }

    #[test]
    fn suite_is_deterministic() {
        let a = run_gap_suite(&GapConfig::tiny());
        let b = run_gap_suite(&GapConfig::tiny());
        for (x, y) in a.rows.iter().zip(&b.rows) {
            assert_eq!(x.heuristic, y.heuristic);
            assert_eq!(x.certified, y.certified);
            assert_eq!(x.max_gap, y.max_gap);
            assert_eq!(x.mean_gap, y.mean_gap);
        }
    }

    #[test]
    fn profiles_produce_their_shapes() {
        let mut rng = Pcg64::new(1);
        for p in PROFILES {
            let lens = p.lengths(&mut rng, 40);
            assert_eq!(lens.len(), 40);
            assert!(lens.iter().all(|&l| l >= 1), "{}", p.name);
        }
        let giant = GapProfile {
            name: "one-giant",
            kind: ProfileKind::OneGiant,
        };
        let lens = giant.lengths(&mut rng, 30);
        assert!(lens.iter().any(|&l| l >= 2_000));
    }

    #[test]
    fn json_roundtrip_exposes_overall_gaps() {
        let report = run_gap_suite(&GapConfig::tiny());
        let j = report.to_json();
        assert_eq!(j.get("bench").as_str(), Some("balancer_gaps"));
        for &h in GAP_HEURISTICS {
            assert!(
                j.get("overall").get(h).get("max_gap").as_f64().is_some(),
                "{h} missing from overall"
            );
        }
        let rows = j.get("rows").as_arr().unwrap();
        assert_eq!(rows.len(), report.rows.len());
    }

    #[test]
    fn gate_fails_when_a_heuristic_has_no_certified_cases() {
        // A cost model the oracle stops certifying must fail the gate
        // loudly, not pass with a vacuous 0.0 gap.
        let mut report = run_gap_suite(&GapConfig::tiny());
        for r in &mut report.rows {
            if r.heuristic == "quadratic" {
                r.certified = 0;
            }
        }
        let generous = Json::parse(
            r#"{"slack": 0.0, "max_gap": {"greedy": 10.0, "kk": 10.0,
                "quadratic": 10.0, "padded": 10.0, "convpad": 10.0}}"#,
        )
        .unwrap();
        let regressions = report.check_baseline(&generous);
        assert!(
            regressions
                .iter()
                .any(|r| r.contains("quadratic") && r.contains("unmeasured")),
            "{regressions:?}"
        );
    }

    #[test]
    fn baseline_gate_passes_and_fails_correctly() {
        let report = run_gap_suite(&GapConfig::tiny());
        // Generous ceilings: must pass.
        let pass = Json::parse(
            r#"{"slack": 0.02, "max_gap": {"greedy": 10.0, "kk": 10.0,
                "quadratic": 10.0, "padded": 10.0, "convpad": 10.0}}"#,
        )
        .unwrap();
        assert!(report.check_baseline(&pass).is_empty());
        // Impossible ceilings: every heuristic with a positive gap
        // regresses, and a missing entry is itself a failure.
        let fail = Json::parse(
            r#"{"slack": 0.0, "max_gap": {"greedy": -1.0}}"#,
        )
        .unwrap();
        let regressions = report.check_baseline(&fail);
        assert!(
            regressions.iter().any(|r| r.contains("greedy")),
            "{regressions:?}"
        );
        assert!(
            regressions.iter().any(|r| r.contains("no baseline entry")),
            "{regressions:?}"
        );
    }
}
