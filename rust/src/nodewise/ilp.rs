//! Exact node-wise rearrangement by branch-and-bound.
//!
//! Formulation (equivalent to the paper's ILP): assign each logical
//! destination batch `j` to a node `m` (capacity c batches per node).
//! Instance `i`'s inter-node send volume is
//!
//! ```text
//! cost_i = T_i − Σ_{j → node(i)} V[i][j]
//! ```
//!
//! where `T_i = Σ_j V[i][j]` minus the traffic V[i][j] for batches
//! placed on i's own node (intra-node traffic is free under Eq. 5).
//! Minimize `max_i cost_i`. Batches are branched in decreasing total
//! volume; the bound below is admissible so the first complete solution
//! found under best-first cannot be improved once the open set's bound
//! exceeds the incumbent.

use crate::comm::topology::Topology;
use crate::comm::volume::VolumeMatrix;

use super::NodewisePlan;

struct Search<'a> {
    topo: &'a Topology,
    v: &'a VolumeMatrix,
    d: usize,
    nodes: usize,
    /// batch order for branching (indices into 0..d).
    order: Vec<usize>,
    /// total send volume per instance.
    totals: Vec<f64>,
    best_obj: f64,
    best_assign: Vec<usize>, // batch -> node
}

impl<'a> Search<'a> {
    /// Objective if every *remaining* batch could be placed optimally
    /// for each instance independently (admissible lower bound): each
    /// instance keeps its current savings plus the max possible savings
    /// from remaining batches, capped by node capacity.
    fn lower_bound(
        &self,
        savings: &[f64],
        node_left: &[usize],
        placed: usize,
    ) -> f64 {
        // cost_i >= T_i - savings_i - (best-case future savings for i).
        // Future savings for instance i are at most the sum of the
        // largest (capacity left on i's node) volumes among unplaced
        // batches.
        let mut bound = 0.0f64;
        for i in 0..self.d {
            let m = self.topo.node_of(i);
            let cap_left = node_left[m];
            if cap_left == 0 {
                bound = bound.max(self.totals[i] - savings[i]);
                continue;
            }
            let mut vols: Vec<f64> = self.order[placed..]
                .iter()
                .map(|&j| self.v.get(i, j))
                .collect();
            vols.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
            let future: f64 = vols.iter().take(cap_left).sum();
            bound = bound.max(self.totals[i] - savings[i] - future);
        }
        bound.max(0.0)
    }

    fn dfs(
        &mut self,
        placed: usize,
        assign: &mut Vec<usize>,
        savings: &mut Vec<f64>,
        node_left: &mut Vec<usize>,
    ) {
        if placed == self.d {
            // Objective: max over instances of totals - savings.
            let obj = (0..self.d)
                .map(|i| self.totals[i] - savings[i])
                .fold(0.0f64, f64::max);
            if obj < self.best_obj {
                self.best_obj = obj;
                self.best_assign = assign.clone();
            }
            return;
        }
        if self.lower_bound(savings, node_left, placed) >= self.best_obj {
            return; // prune
        }
        let j = self.order[placed];
        // Try nodes in descending savings for this batch (good-first).
        let mut cand: Vec<usize> =
            (0..self.nodes).filter(|&m| node_left[m] > 0).collect();
        let node_saving = |m: usize| -> f64 {
            (0..self.d)
                .filter(|&i| self.topo.node_of(i) == m)
                .map(|i| self.v.get(i, j))
                .sum()
        };
        cand.sort_unstable_by(|&a, &b| {
            node_saving(b).partial_cmp(&node_saving(a)).unwrap()
        });
        for m in cand {
            node_left[m] -= 1;
            assign[j] = m;
            let members: Vec<usize> = (0..self.d)
                .filter(|&i| self.topo.node_of(i) == m)
                .collect();
            for &i in &members {
                savings[i] += self.v.get(i, j);
            }
            self.dfs(placed + 1, assign, savings, node_left);
            for &i in &members {
                savings[i] -= self.v.get(i, j);
            }
            node_left[m] += 1;
        }
    }
}

/// Exact branch-and-bound solve. Exponential worst case — intended for
/// d ≤ 16 (≤ 2 nodes of 8, or 4 nodes of 4) and as the oracle for the
/// local-search solver's tests.
pub fn solve_exact(topo: &Topology, v: &VolumeMatrix) -> NodewisePlan {
    let d = v.d;
    let nodes = topo.nodes();
    let cap = topo.per_node;
    let totals: Vec<f64> =
        (0..d).map(|i| (0..d).map(|j| v.get(i, j)).sum()).collect();

    // Branch on batches in decreasing total volume (most constrained
    // first).
    let mut order: Vec<usize> = (0..d).collect();
    let batch_vol = |j: usize| -> f64 {
        (0..d).map(|i| v.get(i, j)).sum()
    };
    order.sort_unstable_by(|&a, &b| {
        batch_vol(b).partial_cmp(&batch_vol(a)).unwrap()
    });

    // Seed the incumbent with the identity assignment so pruning has a
    // finite bound immediately.
    let identity = NodewisePlan::identity(d, topo, v);
    let mut search = Search {
        topo,
        v,
        d,
        nodes,
        order,
        totals,
        best_obj: identity.max_inter + 1e-9,
        best_assign: (0..d).map(|j| topo.node_of(j)).collect(),
    };
    let mut assign = search.best_assign.clone();
    let mut savings = vec![0.0; d];
    let mut node_left = vec![cap; nodes];
    // Last node may be partial.
    if d % cap != 0 {
        node_left[nodes - 1] = d % cap;
    }
    search.dfs(0, &mut assign, &mut savings, &mut node_left);

    // Materialize batch->instance permutation from batch->node
    // assignment: fill each node's slots in batch-index order.
    let mut next_slot: Vec<usize> = (0..nodes).map(|m| m * cap).collect();
    let mut perm = vec![0usize; d];
    for j in 0..d {
        let m = search.best_assign[j];
        perm[j] = next_slot[m];
        next_slot[m] += 1;
    }
    NodewisePlan {
        max_inter: v.max_inter_node(topo, &perm),
        total_inter: v.total_inter_node(topo, &perm),
        perm,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn topo(d: usize, c: usize) -> Topology {
        Topology {
            instances: d,
            per_node: c,
            intra_bw: 450e9,
            inter_bw: 50e9,
            base_latency: 0.0,
        }
    }

    #[test]
    fn trivially_local_when_traffic_is_diagonal_blocks() {
        // All traffic targets batches whose index is on the sender's own
        // node under identity — optimum is zero inter-node.
        let t = topo(8, 4);
        let mut v = VolumeMatrix::zeros(8);
        for i in 0..8 {
            let j = (i + 1) % 4 + (i / 4) * 4; // same node block
            v.add(i, j, 100.0);
        }
        let plan = solve_exact(&t, &v);
        assert_eq!(plan.max_inter, 0.0);
    }

    #[test]
    fn finds_the_obvious_swap() {
        // Instance block {0,1} sends everything to batches {2,3} and
        // vice versa: swapping node blocks zeroes inter-node traffic.
        let t = topo(4, 2);
        let mut v = VolumeMatrix::zeros(4);
        v.add(0, 2, 50.0);
        v.add(1, 3, 50.0);
        v.add(2, 0, 50.0);
        v.add(3, 1, 50.0);
        let plan = solve_exact(&t, &v);
        assert_eq!(plan.max_inter, 0.0, "perm={:?}", plan.perm);
    }

    #[test]
    fn exhaustive_verification_small() {
        // Compare B&B optimum against brute-force over all batch->node
        // assignments for d=6, c=2 (90 partitions).
        use crate::nodewise::tests::random_volume;
        use crate::util::rng::Pcg64;
        let t = topo(6, 2);
        let mut rng = Pcg64::new(11);
        for _ in 0..10 {
            let v = random_volume(6, &mut rng, 0.4);
            let plan = solve_exact(&t, &v);
            let brute = brute_force(&t, &v);
            assert!(
                (plan.max_inter - brute).abs() < 1e-6,
                "B&B {} != brute {}",
                plan.max_inter,
                brute
            );
        }
    }

    fn brute_force(t: &Topology, v: &VolumeMatrix) -> f64 {
        let d = v.d;
        let mut perm: Vec<usize> = (0..d).collect();
        let mut best = f64::INFINITY;
        permute(&mut perm, 0, &mut |p: &[usize]| {
            best = best.min(v.max_inter_node(t, p));
        });
        best
    }

    fn permute<F: FnMut(&[usize])>(
        xs: &mut Vec<usize>,
        k: usize,
        f: &mut F,
    ) {
        if k == xs.len() {
            f(xs);
            return;
        }
        for i in k..xs.len() {
            xs.swap(k, i);
            permute(xs, k + 1, f);
            xs.swap(k, i);
        }
    }

    #[test]
    fn prop_never_worse_than_identity() {
        use crate::nodewise::tests::random_volume;
        check("exact <= identity", 40, |g| {
            let c = *g.choose(&[2usize, 4]);
            let nodes = g.usize(2, 4);
            let d = c * nodes;
            let t = topo(d, c);
            let mut rng = crate::util::rng::Pcg64::new(g.seed);
            let v = random_volume(d, &mut rng, g.f64(0.0, 0.8));
            let plan = solve_exact(&t, &v);
            let id = NodewisePlan::identity(d, &t, &v);
            assert!(plan.max_inter <= id.max_inter + 1e-9);
        });
    }
}
