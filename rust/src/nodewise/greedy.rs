//! Local-search node-wise rearrangement (production path for large d).
//!
//! Seed: assign batches to nodes greedily in decreasing total volume,
//! each to the node with the largest capacity-respecting savings.
//! Improve: hill-climb over pairwise batch swaps across nodes, accepting
//! a swap when it lowers (max inter-node send, total inter-node send)
//! lexicographically. At the paper's scale (d = 320, c = 8) this
//! converges in a few passes — well inside the "tens of milliseconds"
//! the paper reports for its CBC solve, and it overlaps with the forward
//! pass anyway (§6).

use crate::comm::topology::Topology;
use crate::comm::volume::VolumeMatrix;

use super::NodewisePlan;

/// Per-node savings table: `save[m][j]` = Σ_{i ∈ node m} V[i][j].
fn node_savings(topo: &Topology, v: &VolumeMatrix) -> Vec<Vec<f64>> {
    let d = v.d;
    let nodes = topo.nodes();
    let mut save = vec![vec![0.0; d]; nodes];
    for i in 0..d {
        let m = topo.node_of(i);
        for j in 0..d {
            save[m][j] += v.get(i, j);
        }
    }
    save
}

/// Per-instance inter-node costs for a batch→node assignment.
fn instance_costs(
    topo: &Topology,
    v: &VolumeMatrix,
    assign: &[usize],
) -> Vec<f64> {
    let d = v.d;
    (0..d)
        .map(|i| {
            let m = topo.node_of(i);
            (0..d)
                .filter(|&j| assign[j] != m)
                .map(|j| v.get(i, j))
                .sum()
        })
        .collect()
}

/// Greedy seed + pairwise-swap hill climbing.
pub fn solve_local(topo: &Topology, v: &VolumeMatrix) -> NodewisePlan {
    let d = v.d;
    let nodes = topo.nodes();
    let cap = topo.per_node;
    let save = node_savings(topo, v);

    // ---- greedy seed -------------------------------------------------
    let mut order: Vec<usize> = (0..d).collect();
    let batch_vol = |j: usize| -> f64 {
        (0..nodes).map(|m| save[m][j]).sum()
    };
    order.sort_unstable_by(|&a, &b| {
        batch_vol(b).partial_cmp(&batch_vol(a)).unwrap()
    });
    let mut node_left = vec![cap; nodes];
    if d % cap != 0 {
        node_left[nodes - 1] = d % cap;
    }
    let mut assign = vec![usize::MAX; d]; // batch -> node
    for &j in &order {
        let m = (0..nodes)
            .filter(|&m| node_left[m] > 0)
            .max_by(|&a, &b| save[a][j].partial_cmp(&save[b][j]).unwrap())
            .expect("capacity always remains");
        assign[j] = m;
        node_left[m] -= 1;
    }

    // ---- pairwise-swap hill climbing -----------------------------------
    // Incremental evaluation: swapping batches a<->b (nodes ma != mb)
    // only changes the costs of the 2c instances on ma and mb, each by
    // ±(V[i][a] - V[i][b]). The candidate max is O(c) when the current
    // argmax instance is unaffected (the common case); only swaps that
    // touch the argmax pay an O(d) rescan. Large d uses a sampled
    // candidate stream instead of all O(d²) pairs, keeping the solve in
    // the paper's "tens of ms" budget at d = 2560.
    let mut costs = instance_costs(topo, v, &assign);
    let mut cur_max = costs.iter().copied().fold(0.0, f64::max);
    let mut cur_total: f64 = costs.iter().sum();
    let members: Vec<Vec<usize>> = (0..nodes)
        .map(|m| (0..d).filter(|&i| topo.node_of(i) == m).collect())
        .collect();

    let try_swap = |a: usize,
                        b: usize,
                        assign: &mut Vec<usize>,
                        costs: &mut Vec<f64>,
                        cur_max: &mut f64,
                        cur_total: &mut f64|
     -> bool {
        let (ma, mb) = (assign[a], assign[b]);
        if ma == mb {
            return false;
        }
        let mut cand_total = *cur_total;
        let mut affected_max = 0.0f64;
        let mut argmax_affected = false;
        for &i in members[ma].iter().chain(&members[mb]) {
            let c = costs[i];
            let nc = if topo.node_of(i) == ma {
                c + v.get(i, a) - v.get(i, b)
            } else {
                c + v.get(i, b) - v.get(i, a)
            };
            cand_total += nc - c;
            affected_max = affected_max.max(nc);
            if c >= *cur_max - 1e-12 {
                argmax_affected = true;
            }
        }
        let cand_max = if argmax_affected {
            // Unaffected max unknown: full rescan with updated values.
            let mut m = affected_max;
            for (i, &c) in costs.iter().enumerate() {
                let mi = topo.node_of(i);
                if mi != ma && mi != mb {
                    m = m.max(c);
                }
            }
            m
        } else {
            affected_max.max(*cur_max)
        };
        if (cand_max, cand_total) < (*cur_max, *cur_total) {
            for &i in members[ma].iter().chain(&members[mb]) {
                costs[i] += if topo.node_of(i) == ma {
                    v.get(i, a) - v.get(i, b)
                } else {
                    v.get(i, b) - v.get(i, a)
                };
            }
            assign.swap(a, b);
            *cur_max = cand_max;
            *cur_total = cand_total;
            true
        } else {
            false
        }
    };

    if d <= 128 {
        // Exhaustive passes.
        for _ in 0..6 {
            let mut improved = false;
            for a in 0..d {
                for b in (a + 1)..d {
                    improved |= try_swap(
                        a, b, &mut assign, &mut costs, &mut cur_max,
                        &mut cur_total,
                    );
                }
            }
            if !improved {
                break;
            }
        }
    } else {
        // Sampled stream: deterministic per (d, volume hash).
        let mut rng = crate::util::rng::Pcg64::new(d as u64 ^ 0xA5A5);
        let budget = (16 * d).min(120_000);
        for _ in 0..budget {
            let a = rng.range(0, d);
            let b = rng.range(0, d);
            if a != b {
                let (a, b) = (a.min(b), a.max(b));
                try_swap(
                    a, b, &mut assign, &mut costs, &mut cur_max,
                    &mut cur_total,
                );
            }
        }
    }

    // Materialize permutation (node slots in batch-index order).
    let mut next_slot: Vec<usize> = (0..nodes).map(|m| m * cap).collect();
    let mut perm = vec![0usize; d];
    for j in 0..d {
        let m = assign[j];
        perm[j] = next_slot[m];
        next_slot[m] += 1;
    }
    NodewisePlan {
        max_inter: v.max_inter_node(topo, &perm),
        total_inter: v.total_inter_node(topo, &perm),
        perm,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodewise::ilp::solve_exact;
    use crate::nodewise::tests::random_volume;
    use crate::util::prop::check;
    use crate::util::rng::Pcg64;

    fn topo(d: usize, c: usize) -> Topology {
        Topology {
            instances: d,
            per_node: c,
            intra_bw: 450e9,
            inter_bw: 50e9,
            base_latency: 0.0,
        }
    }

    #[test]
    fn matches_exact_on_small_instances() {
        let mut rng = Pcg64::new(21);
        let mut exact_wins = 0;
        for trial in 0..20 {
            let t = topo(8, 2);
            let v = random_volume(8, &mut rng, 0.4);
            let local = solve_local(&t, &v);
            let exact = solve_exact(&t, &v);
            assert!(
                local.max_inter >= exact.max_inter - 1e-9,
                "trial {trial}: local beat the optimum?!"
            );
            // Local search should be near-optimal most of the time.
            if local.max_inter > exact.max_inter * 1.25 + 1e-9 {
                exact_wins += 1;
            }
        }
        assert!(exact_wins <= 4, "local search too weak: {exact_wins}/20");
    }

    #[test]
    fn prop_local_never_worse_than_identity_objective() {
        check("local <= identity", 40, |g| {
            let c = *g.choose(&[2usize, 4, 8]);
            let nodes = g.usize(2, 5);
            let d = c * nodes;
            let t = topo(d, c);
            let mut rng = Pcg64::new(g.seed ^ 0xABCD);
            let v = random_volume(d, &mut rng, g.f64(0.0, 0.7));
            let local = solve_local(&t, &v);
            let id = NodewisePlan::identity(d, &t, &v);
            // The greedy seed can in principle lose to identity on max
            // (it optimizes savings, not minimax), but the rearrange()
            // wrapper guards that; here we check the plan is a valid
            // permutation and total never regresses badly.
            let mut p = local.perm.clone();
            p.sort_unstable();
            assert_eq!(p, (0..d).collect::<Vec<_>>());
            assert!(local.total_inter <= id.total_inter * 1.5 + 1e-9);
        });
    }

    #[test]
    fn large_instance_is_fast_and_effective() {
        // d=128, c=8 — the paper's microbenchmark scale.
        let t = topo(128, 8);
        let mut rng = Pcg64::new(33);
        let v = random_volume(128, &mut rng, 0.6);
        let start = std::time::Instant::now();
        let plan = solve_local(&t, &v);
        let elapsed = start.elapsed();
        let id = NodewisePlan::identity(128, &t, &v);
        assert!(
            plan.total_inter < id.total_inter,
            "no reduction: {} vs {}",
            plan.total_inter,
            id.total_inter
        );
        assert!(
            elapsed.as_millis() < 2_000,
            "too slow: {elapsed:?} (paper: tens of ms)"
        );
    }
}
