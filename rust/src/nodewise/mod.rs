//! Node-wise Rearrangement Algorithm (paper §5.2.2, Alg. 3).
//!
//! A Post-Balancing algorithm decides the *contents* of the d new
//! mini-batches but not *which physical instance* hosts which batch: any
//! permutation of the batch order leaves the balancing objective
//! unchanged, yet changes how much All-to-All traffic crosses node
//! boundaries. The paper solves the batch→instance assignment as an ILP
//! (CVXPY + CBC); CBC is unavailable offline, so this module implements:
//!
//! * [`ilp::solve_exact`] — branch-and-bound over batch→node
//!   assignments with an admissible lower bound: exact optimum, used for
//!   d up to ~16 and as the test oracle;
//! * [`greedy::solve_local`] — greedy seeding + pairwise-swap local
//!   search: the production path, tens of microseconds at d = 320.
//!
//! Only node-granular placement matters (traffic within a node is
//! "free" in Eq. 5), so both solvers assign batches to node slots and
//! fix an arbitrary within-node order.

pub mod greedy;
pub mod ilp;

use crate::comm::topology::Topology;
use crate::comm::volume::VolumeMatrix;

/// Result of the node-wise rearrangement: `perm[j]` = physical instance
/// that will host logical destination batch `j`, plus the achieved
/// objective (max inter-node send volume).
#[derive(Clone, Debug)]
pub struct NodewisePlan {
    pub perm: Vec<usize>,
    pub max_inter: f64,
    pub total_inter: f64,
}

impl NodewisePlan {
    pub fn identity(d: usize, topo: &Topology, v: &VolumeMatrix)
        -> NodewisePlan {
        let perm = VolumeMatrix::identity_perm(d);
        NodewisePlan {
            max_inter: v.max_inter_node(topo, &perm),
            total_inter: v.total_inter_node(topo, &perm),
            perm,
        }
    }
}

/// Solve the node-wise rearrangement, choosing exact B&B when the node
/// count is small enough and local search otherwise. Never returns a
/// plan worse than the identity order.
pub fn rearrange(topo: &Topology, v: &VolumeMatrix) -> NodewisePlan {
    let d = v.d;
    let identity = NodewisePlan::identity(d, topo, v);
    if topo.nodes() <= 1 || d <= 1 {
        return identity;
    }
    let plan = if d <= 16 {
        ilp::solve_exact(topo, v)
    } else {
        greedy::solve_local(topo, v)
    };
    if plan.max_inter <= identity.max_inter {
        plan
    } else {
        identity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    pub(crate) fn random_volume(
        d: usize,
        rng: &mut Pcg64,
        sparsity: f64,
    ) -> VolumeMatrix {
        let mut v = VolumeMatrix::zeros(d);
        for i in 0..d {
            for j in 0..d {
                if rng.f64() > sparsity {
                    v.add(i, j, (rng.f64() * 1000.0).round());
                }
            }
        }
        v
    }

    #[test]
    fn rearrange_never_worse_than_identity() {
        let mut rng = Pcg64::new(5);
        for d in [4usize, 8, 16, 32] {
            let mut topo = Topology::h100(d);
            topo.per_node = (d / 4).max(2);
            let v = random_volume(d, &mut rng, 0.3);
            let id = NodewisePlan::identity(d, &topo, &v);
            let plan = rearrange(&topo, &v);
            assert!(
                plan.max_inter <= id.max_inter + 1e-9,
                "d={d}: {} > {}",
                plan.max_inter,
                id.max_inter
            );
            // perm must be a permutation.
            let mut sorted = plan.perm.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..d).collect::<Vec<_>>());
        }
    }

    #[test]
    fn single_node_is_identity() {
        let topo = Topology::h100(8); // 8 instances, one node
        let mut rng = Pcg64::new(6);
        let v = random_volume(8, &mut rng, 0.0);
        let plan = rearrange(&topo, &v);
        assert_eq!(plan.perm, (0..8).collect::<Vec<_>>());
        assert_eq!(plan.max_inter, 0.0);
    }
}
