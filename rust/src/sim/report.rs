//! Table/figure renderers for simulator, trainer, and comm-layer
//! outputs.

use super::engine::RunSummary;
use crate::balance::gaps::GapReport;
use crate::comm::calibrate::Calibration;
use crate::comm::topology::Topology;

/// Render a Fig. 8/9-style grouped bar table: rows = systems, columns =
/// models, cells = (MFU %, TPT tokens/s/GPU).
pub fn render_overall(rows: &[Vec<RunSummary>]) -> String {
    let mut out = String::new();
    if rows.is_empty() || rows[0].is_empty() {
        return out;
    }
    out.push_str(&format!("{:<24}", "system"));
    for cell in &rows[0] {
        out.push_str(&format!(
            "{:>12}{:>4}",
            cell.model_name, ""
        ));
    }
    out.push_str("\n");
    out.push_str(&format!("{:<24}", ""));
    for _ in &rows[0] {
        out.push_str(&format!("{:>8}{:>8}", "MFU%", "TPT"));
    }
    out.push_str("\n");
    for row in rows {
        out.push_str(&format!("{:<24}", row[0].system.name()));
        for cell in row {
            if cell.oom {
                out.push_str(&format!("{:>8}{:>8}", "OOM", "-"));
            } else {
                out.push_str(&format!(
                    "{:>8.1}{:>8.0}",
                    cell.mfu * 100.0,
                    cell.tpt
                ));
            }
        }
        out.push_str("\n");
    }
    out
}

/// Render Table-2-style overhead scaling.
pub fn render_overhead(cells: &[RunSummary]) -> String {
    let mut out = String::new();
    out.push_str(&format!("{:<16}", "GPUs"));
    for c in cells {
        out.push_str(&format!("{:>10}", c.gpus));
    }
    out.push_str("\n");
    out.push_str(&format!("{:<16}", "Overhead (ms)"));
    for c in cells {
        out.push_str(&format!("{:>10.2}", c.dispatcher_overhead_ms));
    }
    out.push_str("\n");
    out.push_str(&format!("{:<16}", "Duration (s)"));
    for c in cells {
        out.push_str(&format!("{:>10.2}", c.step_secs));
    }
    out.push_str("\n");
    out.push_str(&format!("{:<16}", "Plan (ms)"));
    for c in cells {
        out.push_str(&format!("{:>10.2}", c.plan_ms));
    }
    out.push_str("\n");
    // Per-step plan-time percentiles + warm/cold split: means hide the
    // cold-start spike (step 1) and the steady-state warm plateau that
    // the incremental planner creates.
    out.push_str(&format!("{:<16}", "Plan p50 (ms)"));
    for c in cells {
        out.push_str(&format!("{:>10.2}", c.plan_stats.p50_ms));
    }
    out.push_str("\n");
    out.push_str(&format!("{:<16}", "Plan p95 (ms)"));
    for c in cells {
        out.push_str(&format!("{:>10.2}", c.plan_stats.p95_ms));
    }
    out.push_str("\n");
    out.push_str(&format!("{:<16}", "Plan p99 (ms)"));
    for c in cells {
        out.push_str(&format!("{:>10.2}", c.plan_stats.p99_ms));
    }
    out.push_str("\n");
    out.push_str(&format!("{:<16}", "Warm plan (ms)"));
    for c in cells {
        out.push_str(&format!("{:>10.2}", c.plan_stats.warm_ms));
    }
    out.push_str("\n");
    out.push_str(&format!("{:<16}", "Cold plan (ms)"));
    for c in cells {
        out.push_str(&format!("{:>10.2}", c.plan_stats.cold_ms));
    }
    out.push_str("\n");
    out.push_str(&format!("{:<16}", "Warm rate (%)"));
    for c in cells {
        out.push_str(&format!(
            "{:>10.1}",
            c.plan_stats.warm_rate * 100.0
        ));
    }
    out.push_str("\n");
    out.push_str(&format!("{:<16}", "Cache hit (%)"));
    for c in cells {
        out.push_str(&format!(
            "{:>10.1}",
            c.plan_stats.cache_hit_rate * 100.0
        ));
    }
    out.push_str("\n");
    out.push_str(&format!("{:<16}", "Overlapped (%)"));
    for c in cells {
        out.push_str(&format!("{:>10.1}", c.plan_overlapped_pct));
    }
    out.push_str("\n");
    out
}

/// Render an MFU + memory comparison (Fig. 10/12 style).
pub fn render_mfu_memory(rows: &[Vec<RunSummary>]) -> String {
    let mut out = String::new();
    if rows.is_empty() || rows[0].is_empty() {
        return out;
    }
    out.push_str(&format!("{:<24}", "system"));
    for cell in &rows[0] {
        out.push_str(&format!("{:>16}", cell.model_name));
    }
    out.push_str(&format!("\n{:<24}", ""));
    for _ in &rows[0] {
        out.push_str(&format!("{:>8}{:>8}", "MFU%", "mem GB"));
    }
    out.push_str("\n");
    for row in rows {
        out.push_str(&format!("{:<24}", row[0].system.name()));
        for cell in row {
            if cell.oom {
                out.push_str(&format!("{:>8}{:>8.1}", "OOM", cell.peak_mem_gb));
            } else {
                out.push_str(&format!(
                    "{:>8.1}{:>8.1}",
                    cell.mfu * 100.0,
                    cell.peak_mem_gb
                ));
            }
        }
        out.push_str("\n");
    }
    out
}

/// Render the approximation-gap sweep (heuristic vs exact oracle, the
/// `benches/balancer_gaps.rs` output): one row per `(heuristic,
/// profile)` with mean/max gap over oracle-certified cases.
pub fn render_balancer_gaps(report: &GapReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "== approximation gaps vs ilp oracle (node budget {}, \
         certified {:.0}%) ==\n",
        report.node_budget,
        report.certified_fraction() * 100.0
    ));
    out.push_str(&format!(
        "{:<12}{:<14}{:>7}{:>7}{:>10}{:>10}{:>14}\n",
        "heuristic", "profile", "cases", "cert", "mean %", "max %",
        "oracle nodes"
    ));
    for r in &report.rows {
        out.push_str(&format!(
            "{:<12}{:<14}{:>7}{:>7}{:>10.2}{:>10.2}{:>14.0}\n",
            r.heuristic,
            r.profile,
            r.cases,
            r.certified,
            r.mean_gap * 100.0,
            r.max_gap * 100.0,
            r.mean_oracle_nodes
        ));
    }
    out
}

/// Render a fitted transport calibration next to the analytic
/// reference constants the cost models would otherwise use — the
/// "measured vs hard-coded" comparison the comm bench and the
/// `transports --calibrate` CLI print.
pub fn render_calibration(cal: &Calibration, analytic: &Topology) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "\n== transport '{}' @ d = {} ==\n",
        cal.transport, cal.d
    ));
    out.push_str(&format!(
        "{:<14}{:>12}{:>14}\n",
        "collective", "alpha (us)", "beta (GB/s)"
    ));
    for (name, line) in [
        ("all_to_all", &cal.all_to_all),
        ("all_gather", &cal.all_gather),
    ] {
        out.push_str(&format!(
            "{:<14}{:>12.2}{:>14.3}\n",
            name,
            line.alpha_s * 1e6,
            line.beta_bytes_per_s / 1e9
        ));
    }
    out.push_str(&format!(
        "{:<14}{:>12.2}{:>14.3}  (hard-coded costmodel constants)\n",
        "analytic",
        analytic.base_latency * 1e6,
        analytic.min_bw() / 1e9
    ));
    out
}

/// Render a bubble co-scheduling summary (the `sim --pp-stages` rows):
/// simulated vs analytic bubble fraction, how much of the bubble the
/// encoder packing reclaimed, per-stage occupancy before → after, and
/// the projected step-time change.
pub fn render_cosched(r: &crate::sim::pipeline::CoschedReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "== bubble co-scheduling (pp = {}, microbatches = {}) ==\n",
        r.pp_stages, r.microbatches
    ));
    out.push_str(&format!(
        "{:<26}{:>8.2}%  (analytic (p-1)/(m+p-1): {:.2}%)\n",
        "bubble fraction",
        r.bubble_fraction * 100.0,
        r.analytic_bubble_fraction * 100.0
    ));
    out.push_str(&format!(
        "{:<26}{:>8.2}%  (residual bubble: {:.2}%)\n",
        "bubble occupancy",
        r.occupancy * 100.0,
        r.bubble_fraction_after * 100.0
    ));
    out.push_str(&format!(
        "{:<26}{:>8.2} s packed, {:.2} s left in the prologue\n",
        "encoder work",
        r.packed_secs,
        r.residual_secs
    ));
    out.push_str(&format!("{:<26}", "stage occupancy"));
    for s in 0..r.pp_stages {
        out.push_str(&format!(
            "  s{}: {:.0}%->{:.0}%",
            s,
            r.stage_occupancy_before[s] * 100.0,
            r.stage_occupancy_after[s] * 100.0
        ));
    }
    out.push('\n');
    out.push_str(&format!(
        "{:<26}{:>8.3} s -> {:.3} s  ({:+.2}% step time, {:.3}x)\n",
        "projected step",
        r.baseline_step_secs,
        r.cosched_step_secs,
        -100.0 * r.step_delta_secs()
            / r.baseline_step_secs.max(f64::MIN_POSITIVE),
        r.speedup()
    ));
    out
}

/// Render the world-size transitions an elastic run survived (appended
/// to the loss curve by `TrainReport::render`).
pub fn render_transitions(
    ts: &[crate::trainer::elastic::WorldTransition],
) -> String {
    let mut out = String::new();
    for t in ts {
        out.push_str(&format!(
            "  step {:>4}  world {} -> {} (epoch {}, dead: {:?})\n",
            t.step, t.from, t.to, t.epoch, t.dead
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::{simulate_run, SystemKind};
    use crate::model::config::MllmConfig;

    #[test]
    fn renders_tables_without_panic() {
        let model = MllmConfig::mllm_10b();
        let a = simulate_run(SystemKind::OrchMllm, &model, 16, 8, 1, 1);
        let b = simulate_run(SystemKind::NoBalance, &model, 16, 8, 1, 1);
        let s = render_overall(&[vec![a.clone()], vec![b.clone()]]);
        assert!(s.contains("OrchMLLM"));
        let s2 = render_overhead(&[a.clone()]);
        assert!(s2.contains("Overhead"));
        assert!(s2.contains("Plan p99"));
        assert!(s2.contains("Warm plan"));
        assert!(s2.contains("Cache hit"));
        let s3 = render_mfu_memory(&[vec![a], vec![b]]);
        assert!(s3.contains("mem GB"));
    }

    #[test]
    fn renders_cosched_summary() {
        use crate::sim::pipeline::CoschedReport;
        let r = CoschedReport {
            pp_stages: 2,
            microbatches: 8,
            bubble_fraction: 0.1111,
            analytic_bubble_fraction: 0.1111,
            occupancy: 0.5,
            bubble_fraction_after: 0.0556,
            packed_secs: 0.010,
            residual_secs: 0.002,
            baseline_step_secs: 0.250,
            cosched_step_secs: 0.242,
            stage_occupancy_before: vec![0.89, 0.89],
            stage_occupancy_after: vec![0.94, 0.94],
        };
        let s = render_cosched(&r);
        assert!(s.contains("pp = 2, microbatches = 8"), "{s}");
        assert!(s.contains("11.11%"), "{s}");
        assert!(s.contains("(p-1)/(m+p-1)"), "{s}");
        assert!(s.contains("s0: 89%->94%"), "{s}");
        assert!(s.contains("projected step"), "{s}");
        assert!(s.contains("1.033x"), "{s}");
    }

    #[test]
    fn renders_world_transitions() {
        use crate::trainer::elastic::WorldTransition;
        let s = render_transitions(&[WorldTransition {
            step: 3,
            epoch: 1,
            from: 4,
            to: 3,
            dead: vec![2],
        }]);
        assert!(s.contains("world 4 -> 3"), "{s}");
        assert!(s.contains("epoch 1"), "{s}");
    }

    #[test]
    fn renders_gap_table() {
        use crate::balance::gaps::{run_gap_suite, GapConfig};
        let report = run_gap_suite(&GapConfig::tiny());
        let s = render_balancer_gaps(&report);
        assert!(s.contains("ilp oracle"), "{s}");
        assert!(s.contains("greedy"));
        assert!(s.contains("one-giant"));
        assert!(s.contains("max %"));
    }

    #[test]
    fn renders_calibration_table() {
        use crate::comm::calibrate::FittedLine;
        let cal = Calibration {
            transport: "tcp".into(),
            d: 4,
            all_to_all: FittedLine {
                alpha_s: 25e-6,
                beta_bytes_per_s: 3.2e9,
            },
            all_gather: FittedLine {
                alpha_s: 40e-6,
                beta_bytes_per_s: 2.5e9,
            },
            all_to_all_points: vec![(1024.0, 26e-6)],
            all_gather_points: vec![(1024.0, 41e-6)],
        };
        let s = render_calibration(&cal, &Topology::h100(4));
        assert!(s.contains("transport 'tcp'"));
        assert!(s.contains("all_to_all"));
        assert!(s.contains("analytic"));
        assert!(s.contains("25.00"), "{s}");
    }
}
