//! Discrete-event cluster simulator.
//!
//! Reprices [`crate::orchestrator::StepPlan`]s on a modelled GPU cluster
//! (paper testbed: H100 nodes, NVLink + IB) to regenerate the paper's
//! evaluation — Fig. 8/9 overall MFU/TPT, Table 2 overhead scaling,
//! and the Fig. 10–13 ablations. The same plan objects drive the real
//! trainer, so the simulator measures the shipped logic, only the
//! silicon is analytic.

pub mod engine;
pub mod gpu;
pub mod megatron;
pub mod pipeline;
pub mod report;

pub use engine::{
    simulate_run, simulate_run_archived, simulate_run_named,
    simulate_run_opts, simulate_step, ArchiveRunInfo, RunSummary,
    SimOptions, StepSim, SystemKind,
};
pub use gpu::GpuSpec;
pub use pipeline::{
    coschedule, CoschedPlan, CoschedReport, PipelineParallelConfig,
};
