//! Bubble-aware encoder co-scheduling over a planned step.
//!
//! Per DP rank, the step's serialized shape is
//!
//! ```text
//!   baseline:  Π  | vision+audio encoders | LLM 1F1B pipeline
//!   cosched:   Π  | residual encoder work | LLM 1F1B pipeline
//!                       (the rest runs inside the pipeline's bubbles)
//! ```
//!
//! The co-scheduler prices each rank's encoder-phase workload with the
//! same per-unit α costs the balancers use (carried in
//! [`PipelineParallelConfig`]), splits it into `m` per-microbatch
//! chunks, and greedily packs them earliest-deadline-first into the
//! rank's 1F1B idle intervals. The validity invariant: **no encoder
//! chunk may overlap its consumer's first LLM microbatch** — a chunk
//! feeding microbatch `k` must finish before `F(stage 0, k)` starts.
//! Chunks are divisible (an encoder microbatch is itself a batch of
//! independent sequences), so packing fills interval prefixes exactly.
//!
//! Deadline-infeasible remainders stay in the step's serial prologue —
//! but the bubble capacity they could not use may host *lookahead*
//! chunks: the next step's encoder work, which has no deadline in this
//! step's pipeline. In steady state consecutive steps are symmetric,
//! so lookahead seconds packed here reduce the modelled prologue
//! one-for-one (total packed work never exceeds one step's encoder
//! seconds). The Π rearrangement cost is a collective all ranks run
//! before the first microbatch; it is never packable and is charged to
//! the prologue of both the baseline and the co-scheduled shape.

use crate::model::flops::PhaseKind;
use crate::orchestrator::global::StepPlan;

use super::schedule::build_1f1b;
use super::timeline::PipelineTimeline;
use super::{PipelineParallelConfig, MAX_PP_STAGES};

/// Forward share of a fwd+bwd op pair: `1 / (1 + bwd_mult)` with the
/// cost models' universal `bwd_mult = 2.0`
/// (see [`crate::model::flops::SubmoduleCost`]).
const FWD_FRACTION: f64 = 1.0 / 3.0;

/// Ignore placements below this size (seconds) to stop the splitting
/// packer from shaving unbounded slivers.
const MIN_FRAGMENT_SECS: f64 = 1e-9;

/// One piece of encoder work placed into a bubble.
#[derive(Clone, Copy, Debug)]
pub struct Placement {
    pub stage: usize,
    pub start: f64,
    pub end: f64,
    pub phase: PhaseKind,
    /// Consumer microbatch for this step's chunks; `None` for
    /// lookahead work (next step's encoders, no deadline here).
    pub micro: Option<usize>,
}

/// One rank's co-scheduling outcome.
#[derive(Clone, Debug)]
pub struct RankCosched {
    pub rank: usize,
    pub placements: Vec<Placement>,
    /// Total idle seconds across stages (the bubble budget).
    pub bubble_secs: f64,
    /// Encoder seconds placed into bubbles (deadline + lookahead).
    pub filled_secs: f64,
    /// This rank's packable encoder seconds (vision + audio compute).
    pub enc_secs: f64,
    /// Π rearrangement seconds — prologue-only, never packable.
    pub pi_secs: f64,
    /// Encoder seconds left in the steady-state prologue.
    pub residual_secs: f64,
    /// LLM 1F1B pipeline span for this rank.
    pub pipe_secs: f64,
    /// Per-stage busy seconds before co-scheduling.
    pub stage_busy: Vec<f64>,
    /// Per-stage packed encoder seconds.
    pub stage_filled: Vec<f64>,
}

impl RankCosched {
    /// Serialized step span without co-scheduling.
    pub fn baseline_step_secs(&self) -> f64 {
        self.pi_secs + self.enc_secs + self.pipe_secs
    }

    /// Step span with encoder work folded into the bubbles.
    pub fn cosched_step_secs(&self) -> f64 {
        self.pi_secs + self.residual_secs + self.pipe_secs
    }
}

/// The co-scheduled step: per-rank placements plus the config that
/// produced them.
#[derive(Clone, Debug)]
pub struct CoschedPlan {
    pub cfg: PipelineParallelConfig,
    pub ranks: Vec<RankCosched>,
}

/// The summary the session attaches to a
/// [`PlanReport`](crate::orchestrator::session::PlanReport) and the sim
/// report renders. Rank-mean fractions, straggler (max-over-ranks)
/// step spans — DP collectives synchronize ranks, so the slowest rank
/// sets the step.
#[derive(Clone, Debug)]
pub struct CoschedReport {
    pub pp_stages: usize,
    pub microbatches: usize,
    /// Unscheduled bubble fraction (rank mean).
    pub bubble_fraction: f64,
    /// The closed-form `(p-1)/(m+p-1)` uniform-stage reference.
    pub analytic_bubble_fraction: f64,
    /// Fraction of bubble time filled with encoder work (rank mean).
    /// The unscheduled baseline's occupancy is identically 0.
    pub occupancy: f64,
    /// Bubble fraction left after co-scheduling (rank mean).
    pub bubble_fraction_after: f64,
    /// Encoder seconds packed / left serial (rank mean).
    pub packed_secs: f64,
    pub residual_secs: f64,
    /// Straggler step spans before/after.
    pub baseline_step_secs: f64,
    pub cosched_step_secs: f64,
    /// Per-stage busy fraction before/after (rank mean), `pp_stages`
    /// entries.
    pub stage_occupancy_before: Vec<f64>,
    pub stage_occupancy_after: Vec<f64>,
}

impl CoschedReport {
    /// Projected step-time reduction, seconds.
    pub fn step_delta_secs(&self) -> f64 {
        self.baseline_step_secs - self.cosched_step_secs
    }

    /// Projected speedup factor (>= 1 whenever anything packed).
    pub fn speedup(&self) -> f64 {
        if self.cosched_step_secs <= 0.0 {
            1.0
        } else {
            self.baseline_step_secs / self.cosched_step_secs
        }
    }
}

/// Per-rank totals of a phase's assignment metadata lengths.
fn rank_units(plan: &StepPlan, phase: PhaseKind, d: usize) -> Vec<f64> {
    let mut units = vec![0.0f64; d];
    for (i, batch) in plan.assignment(phase).iter().enumerate() {
        units[i] = batch.iter().map(|e| e.len as f64).sum();
    }
    units
}

/// A bubble slot with a fill cursor: `fill..interval.end` is still
/// free.
#[derive(Clone, Copy, Debug)]
struct Slot {
    stage: usize,
    start: f64,
    end: f64,
    fill: f64,
}

/// Greedily pack one rank's encoder chunks into its pipeline bubbles.
fn pack_rank(
    rank: usize,
    tl: &PipelineTimeline,
    vis_secs: f64,
    aud_secs: f64,
    pi_secs: f64,
) -> RankCosched {
    let p = tl.pp_stages;
    let m = tl.microbatches;
    let enc_secs = vis_secs + aud_secs;

    // Bubble slots in start order across all stages.
    let mut slots: Vec<Slot> = Vec::new();
    for (s, st) in tl.stages.iter().enumerate() {
        for iv in &st.idle {
            slots.push(Slot {
                stage: s,
                start: iv.start,
                end: iv.end,
                fill: iv.start,
            });
        }
    }
    slots.sort_by(|a, b| {
        a.start.partial_cmp(&b.start).unwrap_or(std::cmp::Ordering::Equal)
    });

    // m per-microbatch chunks per present phase, EDF by construction:
    // F(0, k) starts are monotone in k, so micro-major order is
    // deadline order. A deadline-infeasible remainder retries with no
    // deadline as lookahead (next step's work).
    let mut placements: Vec<Placement> = Vec::new();
    let mut stage_filled = vec![0.0f64; p];
    let mut lookahead_pool = 0.0f64;
    let place = |slots: &mut [Slot],
                 stage_filled: &mut [f64],
                 placements: &mut Vec<Placement>,
                 mut remaining: f64,
                 deadline: f64,
                 phase: PhaseKind,
                 micro: Option<usize>|
     -> f64 {
        for slot in slots.iter_mut() {
            if remaining <= MIN_FRAGMENT_SECS {
                break;
            }
            let cap = slot.end.min(deadline) - slot.fill;
            if cap <= MIN_FRAGMENT_SECS {
                continue;
            }
            let take = remaining.min(cap);
            placements.push(Placement {
                stage: slot.stage,
                start: slot.fill,
                end: slot.fill + take,
                phase,
                micro,
            });
            stage_filled[slot.stage] += take;
            slot.fill += take;
            remaining -= take;
        }
        remaining
    };

    for k in 0..m {
        let deadline = tl.first_llm_start(k);
        for (phase, total) in
            [(PhaseKind::Vision, vis_secs), (PhaseKind::Audio, aud_secs)]
        {
            if total <= 0.0 {
                continue;
            }
            let left = place(
                &mut slots,
                &mut stage_filled,
                &mut placements,
                total / m as f64,
                deadline,
                phase,
                Some(k),
            );
            lookahead_pool += left;
        }
    }
    // Lookahead: what missed its deadline re-enters as next-step work
    // with no deadline in this pipeline. Vision/audio identity no
    // longer matters for the accounting; tag it Vision for rendering.
    // Whatever fits nowhere at all stays in `enc - filled` (the
    // residual prologue) below.
    if lookahead_pool > MIN_FRAGMENT_SECS {
        let _ = place(
            &mut slots,
            &mut stage_filled,
            &mut placements,
            lookahead_pool,
            f64::INFINITY,
            PhaseKind::Vision,
            None,
        );
    }

    let filled_secs: f64 = stage_filled.iter().sum();
    RankCosched {
        rank,
        placements,
        bubble_secs: tl.total_idle_secs(),
        filled_secs,
        enc_secs,
        pi_secs,
        residual_secs: (enc_secs - filled_secs).max(0.0),
        pipe_secs: tl.makespan,
        stage_busy: (0..p).map(|s| tl.stages[s].busy_secs()).collect(),
        stage_filled,
    }
}

/// Re-derive a rank's timeline and verify the packing invariants:
/// every placement sits inside an idle interval of its stage, no two
/// placements on a stage overlap, and every deadline chunk ends before
/// its consumer's first LLM microbatch starts.
pub fn check_rank(
    tl: &PipelineTimeline,
    rc: &RankCosched,
) -> Result<(), String> {
    const EPS: f64 = 1e-9;
    let mut by_stage: Vec<Vec<&Placement>> =
        vec![Vec::new(); tl.pp_stages];
    for pl in &rc.placements {
        if pl.stage >= tl.pp_stages {
            return Err(format!("placement on nonexistent stage {}", pl.stage));
        }
        let inside = tl.stages[pl.stage]
            .idle
            .iter()
            .any(|iv| pl.start >= iv.start - EPS && pl.end <= iv.end + EPS);
        if !inside {
            return Err(format!(
                "rank {} placement [{:.6}, {:.6}) not inside an idle \
                 interval of stage {}",
                rc.rank, pl.start, pl.end, pl.stage
            ));
        }
        if let Some(k) = pl.micro {
            let deadline = tl.first_llm_start(k);
            if pl.end > deadline + EPS {
                return Err(format!(
                    "rank {} chunk for microbatch {k} ends at {:.6} after \
                     its consumer's first LLM microbatch starts at {:.6}",
                    rc.rank, pl.end, deadline
                ));
            }
        }
        by_stage[pl.stage].push(pl);
    }
    for (s, mut pls) in by_stage.into_iter().enumerate() {
        pls.sort_by(|a, b| {
            a.start.partial_cmp(&b.start).unwrap_or(std::cmp::Ordering::Equal)
        });
        for w in pls.windows(2) {
            if w[0].end > w[1].start + EPS {
                return Err(format!(
                    "rank {} stage {s}: overlapping placements",
                    rc.rank
                ));
            }
        }
    }
    Ok(())
}

/// Build one rank's 1F1B timeline from its LLM token load under `cfg`.
fn rank_timeline(
    cfg: &PipelineParallelConfig,
    llm_tokens: f64,
) -> Option<PipelineTimeline> {
    let llm_secs = llm_tokens * cfg.llm_secs_per_token;
    if llm_secs <= 0.0 {
        return None;
    }
    let p = cfg.pp_stages;
    let m = cfg.microbatches;
    let shares = cfg.stage_shares();
    let mut fwd = [0.0f64; MAX_PP_STAGES];
    let mut bwd = [0.0f64; MAX_PP_STAGES];
    for s in 0..p {
        let stage_secs = llm_secs * shares[s] / m as f64;
        fwd[s] = stage_secs * FWD_FRACTION;
        bwd[s] = stage_secs * (1.0 - FWD_FRACTION);
    }
    Some(build_1f1b(p, m, &fwd[..p], &bwd[..p]))
}

/// Co-schedule a planned step's encoder phases into its LLM pipeline
/// bubbles. Panics only on internal invariant violations (the packing
/// is re-checked against the timeline it was built from); validate the
/// config with [`PipelineParallelConfig::validate`] before calling.
pub fn coschedule(
    plan: &StepPlan,
    cfg: &PipelineParallelConfig,
) -> CoschedPlan {
    let d = plan.d;
    let vis = rank_units(plan, PhaseKind::Vision, d);
    let aud = rank_units(plan, PhaseKind::Audio, d);
    let llm = rank_units(plan, PhaseKind::Llm, d);
    // The composed Π output rearrangements are collectives every rank
    // joins before the first LLM microbatch can assemble its
    // interleaved sequences — prologue on every rank.
    let pi_secs =
        plan.vision.out_comm.seconds + plan.audio.out_comm.seconds;

    let mut ranks = Vec::with_capacity(d);
    for i in 0..d {
        let tl = match rank_timeline(cfg, llm[i]) {
            Some(tl) => tl,
            None => continue, // no LLM load, no pipeline to fill
        };
        let rc = pack_rank(
            i,
            &tl,
            vis[i] * cfg.vis_secs_per_unit,
            aud[i] * cfg.aud_secs_per_unit,
            pi_secs,
        );
        if let Err(e) = check_rank(&tl, &rc) {
            panic!("co-scheduler produced an invalid packing: {e}");
        }
        ranks.push(rc);
    }
    CoschedPlan { cfg: *cfg, ranks }
}

impl CoschedPlan {
    /// Aggregate the per-rank outcomes into the report the session
    /// attaches and the renderers print.
    pub fn summarize(&self) -> CoschedReport {
        let p = self.cfg.pp_stages;
        let m = self.cfg.microbatches;
        let n = self.ranks.len().max(1) as f64;
        let mut bubble = 0.0;
        let mut bubble_after = 0.0;
        let mut occupancy = 0.0;
        let mut packed = 0.0;
        let mut residual = 0.0;
        let mut base_step = 0.0f64;
        let mut cos_step = 0.0f64;
        let mut before = vec![0.0f64; p];
        let mut after = vec![0.0f64; p];
        for rc in &self.ranks {
            let span = rc.pipe_secs.max(f64::MIN_POSITIVE);
            let stage_time = p as f64 * span;
            bubble += rc.bubble_secs / stage_time;
            bubble_after += (rc.bubble_secs - rc.filled_secs) / stage_time;
            occupancy += if rc.bubble_secs > 0.0 {
                rc.filled_secs / rc.bubble_secs
            } else {
                0.0
            };
            packed += rc.filled_secs;
            residual += rc.residual_secs;
            base_step = base_step.max(rc.baseline_step_secs());
            cos_step = cos_step.max(rc.cosched_step_secs());
            for s in 0..p {
                before[s] += rc.stage_busy[s] / span;
                after[s] += (rc.stage_busy[s] + rc.stage_filled[s]) / span;
            }
        }
        for s in 0..p {
            before[s] /= n;
            after[s] /= n;
        }
        CoschedReport {
            pp_stages: p,
            microbatches: m,
            bubble_fraction: bubble / n,
            analytic_bubble_fraction: super::analytic_bubble_ratio(p, m),
            occupancy: occupancy / n,
            bubble_fraction_after: bubble_after / n,
            packed_secs: packed / n,
            residual_secs: residual / n,
            baseline_step_secs: base_step,
            cosched_step_secs: cos_step,
            stage_occupancy_before: before,
            stage_occupancy_after: after,
        }
    }
}

/// One swept configuration's outcome in the bubble bench.
#[derive(Clone, Debug)]
pub struct BubbleCell {
    /// Stable gate key: `pp{p}_m{m}_{profile}`.
    pub key: String,
    pub pp_stages: usize,
    pub microbatches: usize,
    pub profile: &'static str,
    pub bubble_fraction: f64,
    pub analytic_bubble_fraction: f64,
    pub occupancy: f64,
    /// Occupancy gain over the unscheduled baseline. The baseline never
    /// places encoder work inside bubbles, so its occupancy is
    /// identically 0 and the improvement equals the occupancy — kept as
    /// its own field so the gate's meaning survives a future baseline
    /// that pre-fills bubbles.
    pub improvement: f64,
    pub baseline_step_secs: f64,
    pub cosched_step_secs: f64,
    pub speedup: f64,
}

/// The full sweep: pp ∈ {2,4,8} × microbatches ∈ {4,8,16} × the four
/// incoherence profiles from [`crate::balance::gaps`]. Cells with
/// `microbatches < pp_stages` are skipped — the CLI validation rejects
/// that shape (no full 1F1B steady state), so the gate does not cover
/// it either.
#[derive(Clone, Debug)]
pub struct BubbleSweep {
    pub smoke: bool,
    pub cells: Vec<BubbleCell>,
}

pub const SWEEP_PP: [usize; 3] = [2, 4, 8];
pub const SWEEP_MICROBATCHES: [usize; 3] = [4, 8, 16];

/// Run the bubble-occupancy sweep. Each cell plans a step over
/// profile-shaped examples through a [`PlanSession`] with
/// `.pipeline(...)` set — the same wiring `orchmllm sim` uses — and
/// reads the attached [`CoschedReport`].
///
/// [`PlanSession`]: crate::orchestrator::session::PlanSession
pub fn run_bubble_sweep(smoke: bool) -> BubbleSweep {
    use crate::balance::gaps::PROFILES;
    use crate::comm::topology::Topology;
    use crate::data::synth::{Example, Task};
    use crate::model::config::MllmConfig;
    use crate::orchestrator::global::OrchestratorConfig;
    use crate::orchestrator::session::{PlanOptions, PlanSession};
    use crate::sim::gpu::GpuSpec;
    use crate::util::rng::Pcg64;

    let model = MllmConfig::mllm_10b();
    let gpu = GpuSpec::h100();
    let (d, mb) = if smoke { (4, 8) } else { (8, 24) };
    let mut root = Pcg64::new(0xB0BB1E);
    let mut cells = Vec::new();
    for (pi, pp) in SWEEP_PP.iter().copied().enumerate() {
        for (mi, m) in SWEEP_MICROBATCHES.iter().copied().enumerate() {
            if m < pp {
                continue; // rejected by PipelineParallelConfig::validate
            }
            for (fi, profile) in PROFILES.iter().enumerate() {
                let cfg =
                    PipelineParallelConfig::from_model(&model, &gpu, pp, m);
                let mut rng =
                    root.fork(((pi * 100 + mi * 10 + fi) as u64) + 1);
                let minibatches: Vec<Vec<Example>> = (0..d)
                    .map(|rank| {
                        let vis = profile.lengths(&mut rng, mb);
                        let aud = profile.lengths(&mut rng, mb);
                        (0..mb)
                            .map(|j| {
                                let text = rng.range(64, 256);
                                Example {
                                    id: rank * mb + j,
                                    task: Task::AvDialogue,
                                    vis_len: vis[j],
                                    aud_len: aud[j],
                                    text_len: text,
                                    vis_tokens: vis[j]
                                        / model.vis_downsample.max(1),
                                    aud_tokens: aud[j]
                                        / model.aud_downsample.max(1),
                                }
                            })
                            .collect()
                    })
                    .collect();
                let mut session = PlanSession::with_defaults(
                    OrchestratorConfig::orchmllm(
                        model.llm.hidden as f64 * 2.0,
                    ),
                    Topology::h100(d),
                );
                let _plan = session.plan_shared(
                    &minibatches,
                    PlanOptions::auto().pipeline(cfg),
                );
                let report = session
                    .report()
                    .and_then(|r| r.cosched.clone())
                    .expect(".pipeline(...) attaches a CoschedReport");
                cells.push(BubbleCell {
                    key: format!("pp{pp}_m{m}_{}", profile.name),
                    pp_stages: pp,
                    microbatches: m,
                    profile: profile.name,
                    bubble_fraction: report.bubble_fraction,
                    analytic_bubble_fraction: report
                        .analytic_bubble_fraction,
                    occupancy: report.occupancy,
                    improvement: report.occupancy,
                    baseline_step_secs: report.baseline_step_secs,
                    cosched_step_secs: report.cosched_step_secs,
                    speedup: report.speedup(),
                });
            }
        }
    }
    BubbleSweep { smoke, cells }
}

impl BubbleSweep {
    /// The `BENCH_pipeline_bubbles.json` payload.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("bench", Json::str("pipeline_bubbles")),
            ("smoke", Json::Bool(self.smoke)),
            (
                "cells",
                Json::arr(self.cells.iter().map(|c| {
                    Json::obj(vec![
                        ("key", Json::str(&c.key)),
                        ("pp_stages", Json::num(c.pp_stages as f64)),
                        ("microbatches", Json::num(c.microbatches as f64)),
                        ("profile", Json::str(c.profile)),
                        ("bubble_fraction", Json::num(c.bubble_fraction)),
                        (
                            "analytic_bubble_fraction",
                            Json::num(c.analytic_bubble_fraction),
                        ),
                        ("occupancy", Json::num(c.occupancy)),
                        ("improvement", Json::num(c.improvement)),
                        (
                            "baseline_step_secs",
                            Json::num(c.baseline_step_secs),
                        ),
                        (
                            "cosched_step_secs",
                            Json::num(c.cosched_step_secs),
                        ),
                        ("speedup", Json::num(c.speedup)),
                    ])
                })),
            ),
        ])
    }

    /// Gate the sweep against `ci/bubble_baseline.json`: every cell
    /// must clear its committed minimum occupancy-improvement floor
    /// (minus `slack`), and every cell must have a floor. Returns the
    /// regression messages (empty = pass).
    pub fn check_baseline(
        &self,
        baseline: &crate::util::json::Json,
    ) -> Vec<String> {
        let slack = baseline.get("slack").as_f64().unwrap_or(0.0);
        let floors = baseline.get("min_occupancy_improvement");
        let mut regressions = Vec::new();
        for c in &self.cells {
            match floors.get(&c.key).as_f64() {
                None => regressions.push(format!(
                    "cell {} has no floor in the baseline — add one \
                     (see the _doc re-baselining procedure)",
                    c.key
                )),
                Some(floor) => {
                    if c.improvement + slack < floor {
                        regressions.push(format!(
                            "cell {}: occupancy improvement {:.4} fell \
                             below floor {:.4} (slack {:.3})",
                            c.key, c.improvement, floor, slack
                        ));
                    }
                }
            }
        }
        regressions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::topology::Topology;
    use crate::data::synth::{DatasetConfig, Generator};
    use crate::model::config::MllmConfig;
    use crate::orchestrator::global::OrchestratorConfig;
    use crate::orchestrator::session::{PlanOptions, PlanSession};
    use crate::sim::gpu::GpuSpec;

    fn planned(d: usize, mb: usize) -> StepPlan {
        let model = MllmConfig::mllm_10b();
        let cfg = OrchestratorConfig::orchmllm(model.llm.hidden as f64 * 2.0);
        let mut session =
            PlanSession::with_defaults(cfg, Topology::h100(d));
        let mut generator = Generator::new(DatasetConfig::default(), 7);
        let minibatches: Vec<_> =
            (0..d).map(|_| generator.batch(mb)).collect();
        session.plan(&minibatches, PlanOptions::auto())
    }

    fn cfg(pp: usize, m: usize) -> PipelineParallelConfig {
        PipelineParallelConfig::from_model(
            &MllmConfig::mllm_10b(),
            &GpuSpec::h100(),
            pp,
            m,
        )
    }

    #[test]
    fn coschedule_fills_bubbles_and_shrinks_the_step() {
        let plan = planned(4, 16);
        let report = coschedule(&plan, &cfg(4, 8)).summarize();
        assert!(report.bubble_fraction > 0.0);
        assert!(report.occupancy > 0.0, "nothing packed");
        assert!(report.occupancy <= 1.0 + 1e-9);
        assert!(report.bubble_fraction_after < report.bubble_fraction);
        assert!(
            report.cosched_step_secs < report.baseline_step_secs,
            "cosched {} !< baseline {}",
            report.cosched_step_secs,
            report.baseline_step_secs
        );
        assert!(report.step_delta_secs() > 0.0);
        assert!(report.speedup() > 1.0);
    }

    #[test]
    fn packing_conserves_encoder_work() {
        let plan = planned(4, 16);
        let cp = coschedule(&plan, &cfg(2, 4));
        for rc in &cp.ranks {
            assert!(
                rc.filled_secs <= rc.enc_secs + 1e-9,
                "packed {} > available {}",
                rc.filled_secs,
                rc.enc_secs
            );
            assert!(
                (rc.residual_secs - (rc.enc_secs - rc.filled_secs)).abs()
                    < 1e-9
            );
            let placed: f64 =
                rc.placements.iter().map(|p| p.end - p.start).sum();
            assert!((placed - rc.filled_secs).abs() < 1e-6);
        }
    }

    #[test]
    fn stage_occupancy_rises_everywhere_it_packed() {
        let plan = planned(4, 16);
        let report = coschedule(&plan, &cfg(4, 8)).summarize();
        assert_eq!(report.stage_occupancy_before.len(), 4);
        for s in 0..4 {
            assert!(
                report.stage_occupancy_after[s]
                    >= report.stage_occupancy_before[s] - 1e-12
            );
            assert!(report.stage_occupancy_after[s] <= 1.0 + 1e-9);
        }
        // Late stages have warmup bubbles with early deadlines — the
        // packer must have found some of them.
        let gained: f64 = (0..4)
            .map(|s| {
                report.stage_occupancy_after[s]
                    - report.stage_occupancy_before[s]
            })
            .sum();
        assert!(gained > 0.0);
    }

    #[test]
    fn deadline_invariant_holds_under_check_rank() {
        // Rebuild a rank's timeline independently and re-verify the
        // emitted placements against it.
        let plan = planned(2, 12);
        let c = cfg(4, 8);
        let cp = coschedule(&plan, &c);
        let llm = rank_units(&plan, PhaseKind::Llm, plan.d);
        for rc in &cp.ranks {
            let tl = rank_timeline(&c, llm[rc.rank]).unwrap();
            check_rank(&tl, rc).unwrap();
            // Deadline chunks exist and none dangles past its consumer.
            assert!(rc.placements.iter().any(|p| p.micro.is_some()));
        }
    }

    #[test]
    fn smoke_sweep_strictly_improves_every_cell() {
        // The acceptance criterion in test form: the unscheduled
        // baseline's bubble occupancy is 0 on every cell, and the
        // co-scheduled occupancy must be strictly positive everywhere.
        let sweep = run_bubble_sweep(true);
        // pp {2,4,8} × m {4,8,16} minus the invalid (8,4) cell, × 4
        // profiles.
        assert_eq!(sweep.cells.len(), 8 * 4);
        for c in &sweep.cells {
            assert!(c.improvement > 0.0, "cell {} did not improve", c.key);
            assert!(c.occupancy <= 1.0 + 1e-9, "cell {}", c.key);
            assert!(
                c.cosched_step_secs < c.baseline_step_secs,
                "cell {}: step did not shrink",
                c.key
            );
            assert!(c.bubble_fraction > 0.0, "cell {}", c.key);
        }
    }

    #[test]
    fn sweep_is_deterministic() {
        let a = run_bubble_sweep(true);
        let b = run_bubble_sweep(true);
        for (x, y) in a.cells.iter().zip(&b.cells) {
            assert_eq!(x.key, y.key);
            assert_eq!(x.occupancy, y.occupancy);
            assert_eq!(x.baseline_step_secs, y.baseline_step_secs);
        }
    }

    #[test]
    fn baseline_gate_flags_missing_and_regressed_cells() {
        use crate::util::json::Json;
        let sweep = BubbleSweep {
            smoke: true,
            cells: vec![BubbleCell {
                key: "pp2_m4_heavy-tail".into(),
                pp_stages: 2,
                microbatches: 4,
                profile: "heavy-tail",
                bubble_fraction: 0.2,
                analytic_bubble_fraction: 0.2,
                occupancy: 0.5,
                improvement: 0.5,
                baseline_step_secs: 1.0,
                cosched_step_secs: 0.9,
                speedup: 1.1,
            }],
        };
        // Floor above the measured improvement: regression.
        let bad = Json::parse(
            r#"{"slack": 0.0,
                "min_occupancy_improvement": {"pp2_m4_heavy-tail": 0.9}}"#,
        )
        .unwrap();
        let r = sweep.check_baseline(&bad);
        assert_eq!(r.len(), 1);
        assert!(r[0].contains("fell below floor"), "{}", r[0]);
        // Missing cell: also a regression (gate must stay exhaustive).
        let missing = Json::parse(
            r#"{"slack": 0.0, "min_occupancy_improvement": {}}"#,
        )
        .unwrap();
        let r = sweep.check_baseline(&missing);
        assert_eq!(r.len(), 1);
        assert!(r[0].contains("no floor"), "{}", r[0]);
        // Clearable floor: pass.
        let ok = Json::parse(
            r#"{"slack": 0.02,
                "min_occupancy_improvement": {"pp2_m4_heavy-tail": 0.4}}"#,
        )
        .unwrap();
        assert!(sweep.check_baseline(&ok).is_empty());
    }

    #[test]
    fn zero_encoder_work_packs_nothing() {
        let plan = planned(2, 8);
        let mut c = cfg(2, 4);
        c.vis_secs_per_unit = 0.0;
        c.aud_secs_per_unit = 0.0;
        let report = coschedule(&plan, &c).summarize();
        assert_eq!(report.occupancy, 0.0);
        assert_eq!(report.packed_secs, 0.0);
        assert!(
            (report.baseline_step_secs - report.cosched_step_secs).abs()
                < 1e-12
        );
    }
}
