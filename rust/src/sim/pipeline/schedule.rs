//! The 1F1B pipeline schedule as a dependency-respecting event sweep.
//!
//! Stage `s` of `p` (0-indexed) runs the canonical 1F1B op order:
//! `w_s = min(p-1-s, m)` warmup forwards, then `m - w_s` one-forward-
//! one-backward pairs, then `w_s` cooldown backwards. Cross-stage
//! dependencies: `F(s, k)` waits on `F(s-1, k)`; `B(s, k)` waits on
//! `B(s+1, k)` (and on the same stage's own `F(s, k)`, which the op
//! order already guarantees). Event times come from a fixpoint sweep —
//! each stage executes its sequence in order, an op starting at
//! `max(stage free time, dependency finish time)` — which is exact for
//! any per-stage cost vector, not just uniform stages.
//!
//! Not modelled (documented in DESIGN.md §Pipeline Co-Scheduling):
//! interleaved virtual stages (Megatron's `v>1` schedule), activation
//! send/recv latency between stages (folded into stage cost), and
//! TP-induced per-layer collectives.

use super::timeline::{Interval, PipelineTimeline, StageTimeline};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum OpKind {
    Fwd,
    Bwd,
}

#[derive(Clone, Copy, Debug)]
struct Op {
    kind: OpKind,
    micro: usize,
}

/// The canonical 1F1B op sequence for one stage.
fn stage_ops(p: usize, m: usize, s: usize) -> Vec<Op> {
    let warm = (p - 1 - s).min(m);
    let mut ops = Vec::with_capacity(2 * m);
    for k in 0..warm {
        ops.push(Op { kind: OpKind::Fwd, micro: k });
    }
    for k in 0..(m - warm) {
        ops.push(Op { kind: OpKind::Fwd, micro: warm + k });
        ops.push(Op { kind: OpKind::Bwd, micro: k });
    }
    for k in (m - warm)..m {
        ops.push(Op { kind: OpKind::Bwd, micro: k });
    }
    ops
}

/// Build the exact 1F1B event timeline for `p` stages × `m`
/// microbatches with per-stage forward/backward costs (seconds per
/// microbatch). Panics on shape errors — validate a user-supplied
/// [`PipelineParallelConfig`](super::PipelineParallelConfig) first.
pub fn build_1f1b(
    p: usize,
    m: usize,
    fwd_cost: &[f64],
    bwd_cost: &[f64],
) -> PipelineTimeline {
    assert!(p >= 1 && m >= 1, "need at least one stage and microbatch");
    assert!(
        fwd_cost.len() >= p && bwd_cost.len() >= p,
        "cost vectors shorter than stage count"
    );

    const PENDING: f64 = -1.0;
    let seqs: Vec<Vec<Op>> = (0..p).map(|s| stage_ops(p, m, s)).collect();
    let mut fwd_start = vec![vec![PENDING; m]; p];
    let mut fwd_end = vec![vec![PENDING; m]; p];
    let mut bwd_end = vec![vec![PENDING; m]; p];
    let mut busy: Vec<Vec<Interval>> = vec![Vec::with_capacity(2 * m); p];
    let mut ptr = vec![0usize; p];
    let mut stage_free = vec![0.0f64; p];
    let mut done = 0usize;
    let total = 2 * m * p;

    while done < total {
        let mut progressed = false;
        for s in 0..p {
            while ptr[s] < seqs[s].len() {
                let op = seqs[s][ptr[s]];
                let dep_end = match op.kind {
                    OpKind::Fwd if s == 0 => 0.0,
                    OpKind::Fwd => fwd_end[s - 1][op.micro],
                    // The same-stage F(s,k) precedes B(s,k) in the op
                    // order, so the last stage's backward has no
                    // cross-stage dependency left.
                    OpKind::Bwd if s == p - 1 => 0.0,
                    OpKind::Bwd => bwd_end[s + 1][op.micro],
                };
                if dep_end == PENDING {
                    break; // dependency not scheduled yet
                }
                let cost = match op.kind {
                    OpKind::Fwd => fwd_cost[s],
                    OpKind::Bwd => bwd_cost[s],
                };
                let start = stage_free[s].max(dep_end);
                let end = start + cost;
                match op.kind {
                    OpKind::Fwd => {
                        fwd_start[s][op.micro] = start;
                        fwd_end[s][op.micro] = end;
                    }
                    OpKind::Bwd => bwd_end[s][op.micro] = end,
                }
                // Merge back-to-back ops into one busy interval.
                match busy[s].last_mut() {
                    Some(last) if (last.end - start).abs() < 1e-12 => {
                        last.end = end;
                    }
                    _ => busy[s].push(Interval { start, end }),
                }
                stage_free[s] = end;
                ptr[s] += 1;
                done += 1;
                progressed = true;
            }
        }
        assert!(progressed, "1F1B event sweep deadlocked (internal bug)");
    }

    let makespan = stage_free.iter().cloned().fold(0.0, f64::max);
    let mut tl = PipelineTimeline {
        pp_stages: p,
        microbatches: m,
        makespan,
        stages: busy
            .into_iter()
            .map(|b| StageTimeline { busy: b, idle: Vec::new() })
            .collect(),
        fwd_start,
    };
    tl.fill_idle();
    tl
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_sequences_have_1f1b_shape() {
        let p = 4;
        let m = 8;
        for s in 0..p {
            let ops = stage_ops(p, m, s);
            assert_eq!(ops.len(), 2 * m);
            let warm = p - 1 - s;
            // Warmup prefix is all forwards.
            assert!(ops[..warm].iter().all(|o| o.kind == OpKind::Fwd));
            // Every F(k) precedes its B(k).
            for k in 0..m {
                let fi = ops
                    .iter()
                    .position(|o| o.kind == OpKind::Fwd && o.micro == k)
                    .unwrap();
                let bi = ops
                    .iter()
                    .position(|o| o.kind == OpKind::Bwd && o.micro == k)
                    .unwrap();
                assert!(fi < bi, "stage {s} micro {k}");
            }
        }
    }

    #[test]
    fn uniform_makespan_matches_closed_form() {
        // (m + p - 1) full (f+b) slots for uniform stages.
        for (p, m) in [(2usize, 4usize), (4, 8), (8, 16)] {
            let f = vec![1.0; p];
            let b = vec![1.0; p];
            let tl = build_1f1b(p, m, &f, &b);
            let want = (m + p - 1) as f64 * 2.0;
            assert!(
                (tl.makespan - want).abs() < 1e-9,
                "p={p} m={m}: {} vs {want}",
                tl.makespan
            );
        }
    }

    #[test]
    fn dependencies_are_respected() {
        let p = 4;
        let m = 6;
        let f = [1.0, 2.0, 0.5, 1.5];
        let b = [2.0, 4.0, 1.0, 3.0];
        let tl = build_1f1b(p, m, &f, &b);
        for s in 1..p {
            for k in 0..m {
                // F(s,k) starts at or after F(s-1,k) ends.
                assert!(
                    tl.fwd_start[s][k]
                        >= tl.fwd_start[s - 1][k] + f[s - 1] - 1e-12,
                    "stage {s} micro {k}"
                );
            }
        }
        // Stage 0's first forward starts the pipeline.
        assert_eq!(tl.fwd_start[0][0], 0.0);
        // Deadlines are monotone in the microbatch index.
        for k in 1..m {
            assert!(tl.first_llm_start(k) >= tl.first_llm_start(k - 1));
        }
    }
}
