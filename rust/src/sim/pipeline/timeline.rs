//! Per-stage busy/idle interval accounting for a pipeline schedule.
//!
//! The timeline is exact: every forward/backward op lands as a closed
//! interval on its stage, busy intervals are merged, and idle time is
//! the complement within `[0, makespan]` — including the pre-warmup
//! ramp on late stages and the post-cooldown drain on early ones. For
//! uniform stages this reproduces the classic 1F1B bubble ratio
//! `(p-1)/(m+p-1)` to float precision (pinned test below); for skewed
//! stages it generalizes where the closed form does not.

/// A half-open time interval `[start, end)` in seconds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Interval {
    pub start: f64,
    pub end: f64,
}

impl Interval {
    pub fn len(&self) -> f64 {
        (self.end - self.start).max(0.0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() <= 0.0
    }
}

/// One stage's schedule as merged busy intervals plus their idle
/// complement within the pipeline's `[0, makespan]` window.
#[derive(Clone, Debug, Default)]
pub struct StageTimeline {
    pub busy: Vec<Interval>,
    pub idle: Vec<Interval>,
}

impl StageTimeline {
    pub fn busy_secs(&self) -> f64 {
        self.busy.iter().map(Interval::len).sum()
    }

    pub fn idle_secs(&self) -> f64 {
        self.idle.iter().map(Interval::len).sum()
    }
}

/// The full per-stage event timeline of one pipeline step.
#[derive(Clone, Debug)]
pub struct PipelineTimeline {
    pub pp_stages: usize,
    pub microbatches: usize,
    /// End of the last backward on stage 0 — the step's pipeline span.
    pub makespan: f64,
    pub stages: Vec<StageTimeline>,
    /// `fwd_start[s][k]`: when stage `s` begins the forward of
    /// microbatch `k`. `fwd_start[0][k]` is the co-scheduler's deadline
    /// for encoder chunks feeding microbatch `k`.
    pub fwd_start: Vec<Vec<f64>>,
}

impl PipelineTimeline {
    /// Total idle seconds across all stages within `[0, makespan]`.
    pub fn total_idle_secs(&self) -> f64 {
        self.stages.iter().map(StageTimeline::idle_secs).sum()
    }

    /// Bubble fraction: total idle over total stage-time
    /// (`p · makespan`). Equals `(p-1)/(m+p-1)` for uniform stages.
    pub fn bubble_fraction(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.total_idle_secs() / (self.pp_stages as f64 * self.makespan)
    }

    /// Per-stage busy fraction of the makespan.
    pub fn stage_busy_fraction(&self, stage: usize) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.stages[stage].busy_secs() / self.makespan
    }

    /// Deadline for work that must complete before microbatch `k`
    /// enters the pipeline: the start of `F(stage 0, k)`.
    pub fn first_llm_start(&self, micro: usize) -> f64 {
        self.fwd_start[0][micro]
    }

    /// Rebuild each stage's idle list as the complement of its merged
    /// busy list within `[0, makespan]`. Called once by the builder.
    pub(super) fn fill_idle(&mut self) {
        let makespan = self.makespan;
        for st in &mut self.stages {
            st.idle.clear();
            let mut cursor = 0.0;
            for b in &st.busy {
                if b.start > cursor {
                    st.idle.push(Interval { start: cursor, end: b.start });
                }
                cursor = cursor.max(b.end);
            }
            if makespan > cursor {
                st.idle.push(Interval { start: cursor, end: makespan });
            }
        }
    }
}

/// The classic 1F1B bubble ratio for `p` uniform stages and `m`
/// microbatches: `(p-1)/(m+p-1)`.
pub fn analytic_bubble_ratio(pp_stages: usize, microbatches: usize) -> f64 {
    (pp_stages as f64 - 1.0)
        / (microbatches as f64 + pp_stages as f64 - 1.0)
}

#[cfg(test)]
mod tests {
    use super::super::schedule::build_1f1b;
    use super::*;

    /// The acceptance-criteria cross-check: the event-driven simulator
    /// must reproduce the closed-form uniform-stage bubble ratio to
    /// float tolerance across the whole swept grid.
    #[test]
    fn uniform_stages_reproduce_analytic_bubble_ratio() {
        for p in [2usize, 4, 8] {
            for m in [4usize, 8, 16, 32] {
                if m < p {
                    continue;
                }
                let f = vec![1.0e-3; p];
                let b = vec![2.0e-3; p]; // bwd = 2x fwd, the usual shape
                let tl = build_1f1b(p, m, &f, &b);
                let want = analytic_bubble_ratio(p, m);
                let got = tl.bubble_fraction();
                assert!(
                    (got - want).abs() < 1e-9,
                    "p={p} m={m}: simulated {got} vs analytic {want}"
                );
            }
        }
    }

    #[test]
    fn single_stage_has_no_bubble() {
        let tl = build_1f1b(1, 8, &[1.0], &[2.0]);
        assert!(tl.bubble_fraction().abs() < 1e-12);
        assert!((tl.makespan - 8.0 * 3.0).abs() < 1e-9);
    }

    #[test]
    fn idle_complements_busy_exactly() {
        let tl = build_1f1b(4, 8, &[1.0, 1.5, 0.5, 1.0], &[2.0, 3.0, 1.0, 2.0]);
        for s in 0..4 {
            let st = &tl.stages[s];
            let covered = st.busy_secs() + st.idle_secs();
            assert!(
                (covered - tl.makespan).abs() < 1e-9,
                "stage {s}: busy+idle {covered} vs makespan {}",
                tl.makespan
            );
            // Intervals are disjoint and sorted.
            let mut all: Vec<Interval> = st.busy.clone();
            all.extend(st.idle.iter().copied());
            all.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
            for w in all.windows(2) {
                assert!(w[0].end <= w[1].start + 1e-12);
            }
        }
    }

    #[test]
    fn skewed_stages_bubble_exceeds_uniform() {
        // A slow middle stage starves its neighbours: the bubble
        // fraction must exceed the uniform closed form.
        let tl = build_1f1b(4, 8, &[1.0, 3.0, 1.0, 1.0], &[2.0, 6.0, 2.0, 2.0]);
        assert!(tl.bubble_fraction() > analytic_bubble_ratio(4, 8));
    }
}
