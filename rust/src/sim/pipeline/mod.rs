//! Pipeline-parallel stage timelines and bubble-aware co-scheduling.
//!
//! OrchMLLM's Eq.-2 balancing treats each DP rank as a flat device, but
//! the paper's real deployments run the LLM trunk pipeline-parallel,
//! where 1F1B warmup/cooldown bubbles are the dominant idle time.
//! Optimus and DIP (PAPERS.md) both show the next multiplier comes from
//! filling those bubbles with encoder work. This subsystem adds that
//! axis to the simulator:
//!
//! * [`schedule`] — the 1F1B schedule as an explicit per-stage event
//!   timeline (warmup, steady state, cooldown), built by a
//!   dependency-respecting event sweep;
//! * [`timeline`] — exact bubble accounting over the resulting
//!   per-stage busy/idle intervals, cross-checked against the classic
//!   closed form `(p-1)/(m+p-1)` for uniform stages;
//! * [`cosched`] — a greedy bubble packer that places a [`StepPlan`]'s
//!   encoder-phase work (priced by the same α/β cost models the
//!   balancers use) into LLM-stage idle intervals without violating
//!   consumer dependencies.
//!
//! See DESIGN.md §Pipeline Co-Scheduling for the model's scope (no
//! interleaved virtual stages, no TP interaction) and the invariants.
//!
//! [`StepPlan`]: crate::orchestrator::global::StepPlan

pub mod cosched;
pub mod schedule;
pub mod timeline;

pub use cosched::{coschedule, run_bubble_sweep, BubbleSweep, CoschedPlan, CoschedReport};
pub use schedule::build_1f1b;
pub use timeline::{analytic_bubble_ratio, Interval, PipelineTimeline, StageTimeline};

use crate::model::config::MllmConfig;
use crate::model::flops::PhaseKind;
use crate::sim::engine::phase_costs_opt;
use crate::sim::gpu::GpuSpec;

/// Hard cap on modelled pipeline depth. Large enough for the paper's
/// deepest configuration (PP = 10 on the 84B model) with headroom;
/// fixed-size so [`PipelineParallelConfig`] stays `Copy` and can ride
/// inside [`PlanOptions`](crate::orchestrator::session::PlanOptions)
/// without breaking the zero-alloc warm-plan gate.
pub const MAX_PP_STAGES: usize = 16;

/// Pipeline-parallel shape plus the derived per-unit costs the
/// co-scheduler prices with. Built from a model + GPU via
/// [`PipelineParallelConfig::from_model`]; every constructor output
/// should be checked with [`PipelineParallelConfig::validate`] when the
/// values come from user input.
///
/// Not to be confused with
/// [`PipelineConfig`](crate::orchestrator::pipeline::PipelineConfig),
/// which configures the *lookahead step pipeline* (planner double
/// buffering), an orthogonal concept.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PipelineParallelConfig {
    /// Number of pipeline stages `p` (1..=[`MAX_PP_STAGES`]).
    pub pp_stages: usize,
    /// Microbatches in flight per step `m` (>= `pp_stages`, the 1F1B
    /// requirement for a full steady state).
    pub microbatches: usize,
    /// Relative per-stage cost weights; entries past `pp_stages` are
    /// ignored. Uniform weights model an evenly layer-split trunk;
    /// skewed weights model embedding/head asymmetry.
    pub stage_costs: [f64; MAX_PP_STAGES],
    /// Seconds of LLM forward+backward compute per token on one DP
    /// rank's *whole trunk* (before the per-stage split). Derived from
    /// the α term of the LLM cost model; the β (attention) term is
    /// deliberately dropped — it is sub-1% of α at Table-1 scales and
    /// keeping the config `Copy`-cheap matters more than that last
    /// percent (see DESIGN.md §Pipeline Co-Scheduling).
    pub llm_secs_per_token: f64,
    /// Seconds of encoder forward+backward compute per vision metadata
    /// unit (patch). Zero when the modality is absent.
    pub vis_secs_per_unit: f64,
    /// Seconds of encoder forward+backward compute per audio metadata
    /// unit (frame). Zero when the modality is absent.
    pub aud_secs_per_unit: f64,
}

impl PipelineParallelConfig {
    /// Uniform-stage config with unit per-token costs — the shape the
    /// analytic bubble cross-check runs on, and a usable default for
    /// timeline-only experiments.
    pub fn uniform(pp_stages: usize, microbatches: usize) -> Self {
        PipelineParallelConfig {
            pp_stages,
            microbatches,
            stage_costs: [1.0; MAX_PP_STAGES],
            llm_secs_per_token: 1e-6,
            vis_secs_per_unit: 1e-6,
            aud_secs_per_unit: 1e-6,
        }
    }

    /// Derive the per-unit costs from a model's analytic phase costs on
    /// a given GPU: `α·(1+bwd_mult) / (peak·kernel_eff)` seconds per
    /// unit, i.e. the same pricing [`simulate_step_modes`] applies to a
    /// whole phase, taken per token. Stage weights are uniform (layers
    /// split evenly). Modalities the model does not configure price at
    /// zero.
    ///
    /// [`simulate_step_modes`]: crate::sim::engine::simulate_step_modes
    pub fn from_model(
        model: &MllmConfig,
        gpu: &GpuSpec,
        pp_stages: usize,
        microbatches: usize,
    ) -> Self {
        let costs = phase_costs_opt(model);
        let per_unit = |p: PhaseKind| -> f64 {
            match costs[p as usize] {
                Some(c) => {
                    c.alpha_flops * (1.0 + c.bwd_mult)
                        / (gpu.peak_flops * gpu.kernel_eff)
                }
                None => 0.0,
            }
        };
        PipelineParallelConfig {
            pp_stages,
            microbatches,
            stage_costs: [1.0; MAX_PP_STAGES],
            llm_secs_per_token: per_unit(PhaseKind::Llm),
            vis_secs_per_unit: per_unit(PhaseKind::Vision),
            aud_secs_per_unit: per_unit(PhaseKind::Audio),
        }
    }

    /// Reject shapes the 1F1B model cannot represent, with CLI-grade
    /// messages (mirrors `PipelineConfig::validate` /
    /// `TrainRunConfig::validate`).
    pub fn validate(&self) -> Result<(), String> {
        if self.pp_stages < 1 || self.pp_stages > MAX_PP_STAGES {
            return Err(format!(
                "--pp-stages must be in 1..={MAX_PP_STAGES}, got {}",
                self.pp_stages
            ));
        }
        if self.microbatches < self.pp_stages {
            return Err(format!(
                "--microbatches must be >= --pp-stages ({}), got {} \
                 (1F1B needs at least one microbatch per stage in flight)",
                self.pp_stages, self.microbatches
            ));
        }
        for (s, w) in self.stage_costs[..self.pp_stages].iter().enumerate() {
            if !w.is_finite() || *w <= 0.0 {
                return Err(format!(
                    "stage cost weight {s} must be finite and > 0, got {w}"
                ));
            }
        }
        if self.llm_secs_per_token <= 0.0
            || !self.llm_secs_per_token.is_finite()
        {
            return Err(format!(
                "llm_secs_per_token must be finite and > 0, got {}",
                self.llm_secs_per_token
            ));
        }
        Ok(())
    }

    /// Per-stage share of the trunk cost: `stage_costs` normalized over
    /// the first `pp_stages` entries.
    pub fn stage_shares(&self) -> [f64; MAX_PP_STAGES] {
        let total: f64 = self.stage_costs[..self.pp_stages].iter().sum();
        let mut shares = [0.0; MAX_PP_STAGES];
        for s in 0..self.pp_stages {
            shares[s] = self.stage_costs[s] / total;
        }
        shares
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_rejects_bad_shapes() {
        assert!(PipelineParallelConfig::uniform(2, 8).validate().is_ok());
        let e = PipelineParallelConfig::uniform(0, 8).validate().unwrap_err();
        assert!(e.contains("--pp-stages"), "{e}");
        let e = PipelineParallelConfig::uniform(17, 32)
            .validate()
            .unwrap_err();
        assert!(e.contains("1..=16"), "{e}");
        let e = PipelineParallelConfig::uniform(8, 4).validate().unwrap_err();
        assert!(e.contains("--microbatches"), "{e}");
        let mut bad = PipelineParallelConfig::uniform(2, 8);
        bad.stage_costs[1] = 0.0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn from_model_prices_all_three_phases() {
        let model = MllmConfig::mllm_10b();
        let gpu = GpuSpec::h100();
        let cfg = PipelineParallelConfig::from_model(&model, &gpu, 4, 8);
        assert!(cfg.validate().is_ok());
        assert!(cfg.llm_secs_per_token > 0.0);
        assert!(cfg.vis_secs_per_unit > 0.0);
        assert!(cfg.aud_secs_per_unit > 0.0);
        // The trunk dominates the per-token cost.
        assert!(cfg.llm_secs_per_token > cfg.vis_secs_per_unit);
        let shares = cfg.stage_shares();
        assert!((shares[..4].iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn config_is_copy_and_comparable() {
        let a = PipelineParallelConfig::uniform(2, 8);
        let b = a; // Copy
        assert_eq!(a, b);
    }
}
