//! The step simulator: price a [`StepPlan`] on a modelled cluster.
//!
//! Timing model per training step (all DP instances synchronized by the
//! collectives, so each phase costs its *slowest* instance — the §2.3
//! straggler effect the balancing removes):
//!
//!   step = Σ_phases max_i(phase_flops_i) / (peak·eff)
//!        + dispatcher All-to-All seconds          (§5.2)
//!        + encoder-output rearrangements          (§6, composed)
//!        + gradient synchronization (ZeRO3/FSDP reduce-scatter+gather)
//!        + fixed per-step overhead
//!
//! Memory model per instance: sharded model/optimizer states + peak
//! phase activations (padded batching pays for padding) + communicator
//! staging buffers. OOM ends the run (Fig. 10/12 behaviour).

use std::path::Path;

use crate::balance::balancer::registry;
use crate::balance::types::ExampleRef;
use crate::comm::costmodel::allreduce_cost;
use crate::comm::topology::Topology;
use crate::data::synth::{DatasetConfig, Example, Generator};
use crate::model::config::MllmConfig;
use crate::model::flops::{PhaseKind, SubmoduleCost};
use crate::orchestrator::archive::{encode_step_plan, ArchiveError, WarmStart};
use crate::orchestrator::global::{OrchestratorConfig, StepPlan};
use crate::orchestrator::pipeline::PipelineConfig;
use crate::orchestrator::session::{PlanOptions, PlanSession};
use crate::util::sha256;
use crate::util::stats::Summary;

// Plan-time telemetry now lives with the session that produces it;
// re-exported here so existing consumers (megatron, benches) keep their
// import path.
pub use crate::orchestrator::session::PlanTimeStats;

use super::gpu::GpuSpec;
use super::megatron;
use super::pipeline::{CoschedReport, PipelineParallelConfig};

/// Which system configuration a simulated run models (the bars of the
/// paper's figures).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    /// Full OrchMLLM: tailored per-phase algorithms, node-wise
    /// all-to-all, rearrangement composition.
    OrchMllm,
    /// OrchMLLM w/o any balancing (Fig. 8/9 second baseline).
    NoBalance,
    /// Balance only the LLM phase — the pre-balancing stand-in (Fig. 10).
    LlmOnly,
    /// All-Gather payload communicator (Fig. 12).
    AllGatherComm,
    /// Rigid algorithm ablations (Fig. 11).
    AllPad,
    AllRmpad,
    /// Node-wise rearrangement disabled (Fig. 13).
    NoNodewise,
    /// Rearrangement composition disabled (§6 ablation).
    NoComposition,
    /// Megatron-LM baseline (Fig. 8/9), PP×TP from the paper.
    Megatron,
}

impl SystemKind {
    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::OrchMllm => "OrchMLLM",
            SystemKind::NoBalance => "OrchMLLM w/o balance",
            SystemKind::LlmOnly => "LLM-only balance",
            SystemKind::AllGatherComm => "All-Gather comm",
            SystemKind::AllPad => "all pad",
            SystemKind::AllRmpad => "all rmpad",
            SystemKind::NoNodewise => "w/o node-wise",
            SystemKind::NoComposition => "w/o composition",
            SystemKind::Megatron => "Megatron-LM",
        }
    }

    pub fn parse(s: &str) -> Option<SystemKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "orchmllm" | "orch" => SystemKind::OrchMllm,
            "no-balance" | "nobalance" => SystemKind::NoBalance,
            "llm-only" | "llmonly" => SystemKind::LlmOnly,
            "allgather" | "all-gather" => SystemKind::AllGatherComm,
            "all-pad" | "allpad" => SystemKind::AllPad,
            "all-rmpad" | "allrmpad" => SystemKind::AllRmpad,
            "no-nodewise" => SystemKind::NoNodewise,
            "no-composition" => SystemKind::NoComposition,
            "megatron" | "megatron-lm" => SystemKind::Megatron,
            _ => return None,
        })
    }

    /// Orchestrator configuration realizing this system (None for
    /// Megatron, which has its own model). Balancers resolve through
    /// the [`registry`].
    pub fn orchestrator_config(&self, model: &MllmConfig)
        -> Option<OrchestratorConfig> {
        use crate::orchestrator::dispatcher::Communicator;
        let embed_bytes = model.llm.hidden as f64 * 2.0;
        let mut cfg = OrchestratorConfig::orchmllm(embed_bytes);
        match self {
            SystemKind::OrchMllm => {}
            SystemKind::NoBalance => {
                cfg = OrchestratorConfig::no_balance(embed_bytes)
            }
            SystemKind::LlmOnly => {
                cfg = OrchestratorConfig::llm_only(embed_bytes)
            }
            SystemKind::AllGatherComm => {
                cfg.communicator = Communicator::AllGather;
            }
            SystemKind::AllPad => {
                // Rigid: the padded algorithm everywhere.
                cfg.vision_balancer = registry::must("padded");
                cfg.audio_balancer = registry::must("padded");
            }
            SystemKind::AllRmpad => {
                // Rigid: the no-padding algorithm everywhere.
                cfg.vision_balancer = registry::must("greedy");
                cfg.audio_balancer = registry::must("greedy");
            }
            SystemKind::NoNodewise => {
                cfg.communicator = Communicator::AllToAll { nodewise: false };
            }
            SystemKind::NoComposition => {
                cfg.composition = false;
            }
            SystemKind::Megatron => return None,
        }
        Some(cfg)
    }
}

/// Whether each phase batches with padding (paper §8: patches and LLM
/// sequences without padding, audio with padding).
pub fn phase_padded(phase: PhaseKind) -> bool {
    matches!(phase, PhaseKind::Audio)
}

/// Per-phase padded-batching flags for a system: the *all pad* rigid
/// variant (Fig. 11) pads the vision phase too, paying redundant
/// compute for the padding.
pub fn system_padded(system: SystemKind) -> [bool; 3] {
    match system {
        SystemKind::AllPad => [true, true, false],
        _ => [false, true, false],
    }
}

/// Per-phase analytic costs for a model, `None` for a submodule the
/// config does not carry. Two-modality models (e.g. text+image-only,
/// audio zeroed out) are valid here — use this in any code path that
/// must handle them; a zero-shaped submodule would otherwise flow
/// `α = 0` cost models into the balancers and NaN traits into
/// auto-selection.
pub fn phase_costs_opt(model: &MllmConfig) -> [Option<SubmoduleCost>; 3] {
    [
        model
            .vision
            .is_present()
            .then(|| SubmoduleCost::from_config(&model.vision, 588.0 * 2.0)),
        model
            .audio
            .is_present()
            .then(|| SubmoduleCost::from_config(&model.audio, 128.0 * 2.0)),
        model
            .llm
            .is_present()
            .then(|| SubmoduleCost::from_config(&model.llm, 16.0)),
    ]
}

/// Per-phase analytic costs for a model.
///
/// **Invariant (asserted):** all three submodules must be present.
/// Every Table-1 configuration satisfies this; the simulator's pricing
/// paths assume it. For two-modality models use [`phase_costs_opt`],
/// which represents an absent submodule as `None` instead of silently
/// producing zero-α garbage.
pub fn phase_costs(model: &MllmConfig) -> [SubmoduleCost; 3] {
    let costs = phase_costs_opt(model);
    for (phase, c) in PhaseKind::ALL.iter().zip(&costs) {
        assert!(
            c.is_some(),
            "phase_costs requires all three submodules, but model '{}' \
             has no {:?} submodule — use phase_costs_opt for \
             two-modality models",
            model.name,
            phase
        );
    }
    costs.map(|c| c.expect("checked above"))
}

/// One simulated step's result.
#[derive(Clone, Debug)]
pub struct StepSim {
    pub step_secs: f64,
    pub compute_secs: f64,
    pub comm_secs: f64,
    pub grad_sync_secs: f64,
    /// Non-overlappable remainder of the measured planning time — what
    /// lands on the critical path after hiding behind the forward pass.
    pub dispatcher_secs: f64,
    /// Measured planning wall-time (from [`StepPlan::compute_nanos`]).
    pub plan_secs: f64,
    pub phase_secs: [f64; 3],
    pub effective_flops: f64,
    pub llm_tokens: f64,
    pub peak_mem_bytes: f64,
    pub oom: bool,
    pub mfu: f64,
    /// LLM tokens / second / GPU (the paper's TPT).
    pub tpt: f64,
}

/// Price one planned step with the default batching modes.
pub fn simulate_step(
    model: &MllmConfig,
    topo: &Topology,
    gpu: &GpuSpec,
    plan: &StepPlan,
) -> StepSim {
    simulate_step_modes(
        model,
        topo,
        gpu,
        plan,
        [false, true, false],
    )
}

/// Price one planned step with explicit per-phase padded flags.
pub fn simulate_step_modes(
    model: &MllmConfig,
    topo: &Topology,
    gpu: &GpuSpec,
    plan: &StepPlan,
    padded_modes: [bool; 3],
) -> StepSim {
    let d = topo.instances;
    let costs = phase_costs(model);
    let mut phase_secs = [0.0f64; 3];
    let mut effective_flops = 0.0f64;
    let mut peak_act = vec![0.0f64; d];

    for (pi, phase) in PhaseKind::ALL.iter().enumerate() {
        let padded = padded_modes[pi];
        let cost = &costs[pi];
        let assignment = plan.assignment(*phase);
        let mut slowest = 0.0f64;
        for (i, batch) in assignment.iter().enumerate() {
            let flops = cost.flops(batch, padded);
            slowest = slowest.max(flops);
            effective_flops += cost.effective_flops(batch);
            peak_act[i] += cost.act_bytes(batch, padded);
        }
        phase_secs[pi] = slowest / (gpu.peak_flops * gpu.kernel_eff);
    }
    let compute_secs: f64 = phase_secs.iter().sum();

    // Dispatcher communication (on the critical path, §6).
    let comm_secs = plan.comm_seconds();

    // ZeRO3/FSDP gradient sync: reduce-scatter grads + all-gather params
    // ≈ 3x param bytes; FSDP's prefetch/overlap hides ~85% of it behind
    // backward compute (the paper's hybrid group 256 keeps most traffic
    // within dense islands).
    let param_bytes = 2.0 * model.total_params();
    let grad_sync_secs =
        0.15 * 3.0 * allreduce_cost(topo, param_bytes).seconds;

    // §6 computation-overhead overlapping, now *measured* rather than
    // assumed: the plan was produced in `plan.compute_nanos` of wall
    // time (parallel phase planning: slowest phase, not the sum). It
    // hides behind the forward pass via the step pipeline; only the
    // remainder — if planning ever outlasted compute — lands on the
    // critical path.
    let plan_secs = plan.compute_nanos as f64 / 1e9;
    let dispatcher_secs = (plan_secs - compute_secs).max(0.0);
    let step_secs = compute_secs
        + comm_secs
        + grad_sync_secs
        + dispatcher_secs
        + gpu.step_overhead;

    // Memory: sharded states + activations + comm staging.
    let shard = (topo.instances.min(256)) as f64; // hybrid group (§8.1)
    let state_bytes = 18.0 * model.total_params() / shard;
    let staging = plan
        .vision
        .plan
        .peak_bytes
        .max(plan.audio.plan.peak_bytes)
        .max(plan.llm.peak_bytes);
    let peak_mem_bytes = peak_act
        .iter()
        .map(|a| state_bytes + a + staging)
        .fold(0.0, f64::max);
    let oom = peak_mem_bytes > gpu.mem_bytes * gpu.usable_mem_frac;

    let llm_tokens: f64 = plan
        .assignment(PhaseKind::Llm)
        .iter()
        .flat_map(|b| b.iter())
        .map(|e: &ExampleRef| e.len as f64)
        .sum();

    StepSim {
        step_secs,
        compute_secs,
        comm_secs,
        grad_sync_secs,
        dispatcher_secs,
        plan_secs,
        phase_secs,
        effective_flops,
        llm_tokens,
        peak_mem_bytes,
        oom,
        mfu: effective_flops / (step_secs * gpu.peak_flops * d as f64),
        tpt: llm_tokens / (step_secs * d as f64),
    }
}

/// What the plan archive did for one simulated run (present only when
/// the run was asked to load and/or export an archive).
#[derive(Clone, Debug)]
pub struct ArchiveRunInfo {
    /// An archive was found, fingerprint-matched, and installed.
    pub loaded: bool,
    /// Why the load degraded to a cold start (`None` when `loaded`).
    pub cold_reason: Option<String>,
    /// Fraction of the run's steps replayed whole from the step-level
    /// plan cache — the warm-start hit rate the CI `plan-archive` job
    /// gates on. A same-seed re-run over a loaded archive replays every
    /// step; a cold run replays none (random batches don't recur
    /// within a run).
    pub warm_start_hit_rate: f64,
    /// Whether the *first* step replayed from the (restored) cache —
    /// the bit-identity provenance signal.
    pub first_step_cache_hit: bool,
    /// Content id (sha256 of the canonical encoding) of the first
    /// step's plan; equal across processes when the first step replays
    /// the archived plan.
    pub first_plan_id: Option<String>,
    /// An archive was exported at the end of the run.
    pub exported: bool,
}

/// Aggregate of a simulated multi-step run.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub system: SystemKind,
    pub model_name: &'static str,
    pub gpus: usize,
    pub mini_batch: usize,
    pub steps: usize,
    pub mfu: f64,
    pub tpt: f64,
    pub step_secs: f64,
    pub comm_secs: f64,
    pub peak_mem_gb: f64,
    pub oom: bool,
    pub dispatcher_overhead_ms: f64,
    /// Mean measured planning wall-time per step (ms) — the §6
    /// "computation" share, off the critical path.
    pub plan_ms: f64,
    /// Percentage of planning time hidden behind phase compute (100 =
    /// fully overlapped, the paper's claim).
    pub plan_overlapped_pct: f64,
    /// Plan-time percentiles and warm/cold/cached breakdown.
    pub plan_stats: PlanTimeStats,
    /// Per-dispatcher max-over-instances inter-node bytes (Eq. 5 metric)
    /// for the input rearrangements (Fig.-13), per modality.
    pub inter_node_mb: [f64; 3],
    /// Plan-archive activity for this run (`None` unless the run was
    /// given an archive endpoint via [`simulate_run_archived`]).
    pub archive: Option<ArchiveRunInfo>,
    /// Bubble co-scheduling summary for the run's final step (`None`
    /// unless [`SimOptions::pipeline`] was set). The final step is the
    /// steady-state representative: every step of a run reuses the same
    /// pipeline shape, only the sampled batch varies.
    pub cosched: Option<CoschedReport>,
}

/// Run `steps` simulated iterations of a system on a model+cluster.
pub fn simulate_run(
    system: SystemKind,
    model: &MllmConfig,
    gpus: usize,
    mini_batch: usize,
    steps: usize,
    seed: u64,
) -> RunSummary {
    simulate_run_named(system, model, gpus, mini_batch, steps, seed, None)
}

/// Like [`simulate_run`], with an optional registry balancer name that
/// overrides every phase (the `--balancer` CLI path).
pub fn simulate_run_named(
    system: SystemKind,
    model: &MllmConfig,
    gpus: usize,
    mini_batch: usize,
    steps: usize,
    seed: u64,
    balancer: Option<&str>,
) -> RunSummary {
    simulate_run_archived(
        system, model, gpus, mini_batch, steps, seed, balancer, None, None,
    )
    .expect("simulation without archive endpoints is infallible")
}

/// Like [`simulate_run_named`], with plan-archive endpoints: install a
/// prior run's archive into the session before the first step
/// (`archive_in`) and/or export this run's caches, shape profiles, and
/// plan log after the last (`archive_out`). Archive activity lands in
/// [`RunSummary::archive`]. The only error paths are archive
/// I/O/decode failures; with both endpoints `None` the call cannot
/// fail. Megatron runs have no orchestrator session, so archive
/// endpoints are ignored for them.
#[allow(clippy::too_many_arguments)]
pub fn simulate_run_archived(
    system: SystemKind,
    model: &MllmConfig,
    gpus: usize,
    mini_batch: usize,
    steps: usize,
    seed: u64,
    balancer: Option<&str>,
    archive_in: Option<&Path>,
    archive_out: Option<&Path>,
) -> Result<RunSummary, ArchiveError> {
    simulate_run_opts(
        system,
        model,
        gpus,
        mini_batch,
        steps,
        seed,
        &SimOptions {
            balancer: balancer.map(str::to_string),
            ..SimOptions::default()
        },
    )
}

/// Everything a simulated run can be configured with beyond the core
/// shape — the CLI's `--balancer`/`--gpu`/`--pp-stages`/`--archive*`
/// surface in one place, so new knobs stop growing the argument list.
#[derive(Clone, Debug)]
pub struct SimOptions {
    /// Registry balancer name overriding every phase (`None` = the
    /// system's own configuration).
    pub balancer: Option<String>,
    /// Warm-start the session from this archive directory.
    pub archive_in: Option<std::path::PathBuf>,
    /// Export the session's archive here after the last step.
    pub archive_out: Option<std::path::PathBuf>,
    /// The accelerator to price against.
    pub gpu: GpuSpec,
    /// Bubble co-scheduling: when set, every planned step packs its
    /// encoder phases into the LLM pipeline's 1F1B bubbles and the
    /// summary carries a [`CoschedReport`].
    pub pipeline: Option<PipelineParallelConfig>,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            balancer: None,
            archive_in: None,
            archive_out: None,
            gpu: GpuSpec::h100(),
            pipeline: None,
        }
    }
}

/// The fully-optioned simulated run every `simulate_run*` wrapper
/// resolves to.
pub fn simulate_run_opts(
    system: SystemKind,
    model: &MllmConfig,
    gpus: usize,
    mini_batch: usize,
    steps: usize,
    seed: u64,
    opts: &SimOptions,
) -> Result<RunSummary, ArchiveError> {
    let balancer = opts.balancer.as_deref();
    let archive_in = opts.archive_in.as_deref();
    let archive_out = opts.archive_out.as_deref();
    let topo = Topology::h100(gpus);
    let gpu = opts.gpu;
    let data_cfg = DatasetConfig {
        vis_downsample: model.vis_downsample,
        aud_downsample: model.aud_downsample,
        max_vis: model.max_patches(),
        ..DatasetConfig::default()
    };

    if system == SystemKind::Megatron {
        return Ok(megatron::simulate_megatron(
            model, &gpu, gpus, mini_batch, steps, seed, &data_cfg,
        ));
    }

    let mut cfg = system
        .orchestrator_config(model)
        .expect("non-megatron system");
    if let Some(name) = balancer {
        cfg = if name == crate::balance::select::AUTO {
            cfg.with_auto_balancers(model)
        } else {
            cfg.with_balancer(registry::must(name))
        };
    }
    // The simulator's planning stream is one session: it owns the
    // scratch, histories, and plan caches the loop used to thread by
    // hand, and its stats become the run's plan-time telemetry.
    let mut warm: Option<WarmStart> = None;
    let mut session = match archive_in {
        Some(dir) => {
            let (s, w) = PlanSession::with_archive(
                cfg.clone(),
                PipelineConfig::default(),
                topo,
                dir,
            )?;
            warm = Some(w);
            s
        }
        None => {
            PlanSession::new(cfg.clone(), PipelineConfig::default(), topo)
        }
    };
    if archive_out.is_some() {
        session.set_archive_log(true);
    }
    let mut generator = Generator::new(data_cfg, seed);

    let mut mfu = Summary::new();
    let mut tpt = Summary::new();
    let mut step_s = Summary::new();
    let mut comm_s = Summary::new();
    let mut mem = Summary::new();
    let mut disp_ms = Summary::new();
    let mut overlap = Summary::new();
    let mut inter = [Summary::new(), Summary::new(), Summary::new()];
    let mut oom = false;
    let mut first_step_cache_hit = false;
    let mut first_plan_id: Option<String> = None;
    let mut cosched: Option<CoschedReport> = None;

    let plan_opts = match opts.pipeline {
        Some(cfg) => PlanOptions::auto().pipeline(cfg),
        None => PlanOptions::auto(),
    };
    for step in 0..steps {
        let minibatches: Vec<Vec<Example>> =
            (0..gpus).map(|_| generator.batch(mini_batch)).collect();
        // `plan_shared`, not `plan`: a step-cache replay hands back the
        // archived `Arc` unmodified, so hashing it below reproduces the
        // archived content id bit for bit (`plan` would materialize
        // per-call provenance into the copy and perturb the hash).
        let plan = session.plan_shared(&minibatches, plan_opts);
        if opts.pipeline.is_some() {
            // Keep the latest step's report: the run's steady-state
            // representative (see `RunSummary::cosched`).
            cosched = session.report().and_then(|r| r.cosched.clone());
        }
        if step == 0 && (archive_in.is_some() || archive_out.is_some()) {
            let r = session.report().expect("plan_shared records a report");
            first_step_cache_hit = r.step_cache_hit;
            first_plan_id = Some(sha256::hex(&sha256::sha256(
                &encode_step_plan(&plan),
            )));
        }
        let sim = simulate_step_modes(
            model,
            &topo,
            &gpu,
            &plan,
            system_padded(system),
        );
        mfu.push(sim.mfu);
        tpt.push(sim.tpt);
        step_s.push(sim.step_secs);
        comm_s.push(sim.comm_secs);
        mem.push(sim.peak_mem_bytes);
        // Table-2 "overhead": what lands on the critical path — the
        // All-to-All seconds, a small non-overlappable launch tail, and
        // whatever measured planning time failed to hide behind the
        // forward pass (normally zero: planning is ms-scale, compute is
        // seconds-scale).
        disp_ms.push(
            sim.comm_secs * 1e3 + 0.5 + sim.dispatcher_secs * 1e3,
        );
        overlap.push(if sim.plan_secs > 0.0 {
            100.0 * sim.plan_secs.min(sim.compute_secs) / sim.plan_secs
        } else {
            100.0
        });
        // Fig.-13 metric: inter-node bytes moved by each dispatcher's
        // *input* rearrangement (what the node-wise permutation acts
        // on), per modality.
        let pay = |f: &dyn Fn(&crate::data::synth::Example) -> f64| {
            plan.examples.iter().map(f).collect::<Vec<f64>>()
        };
        inter[0].push(
            plan.vision.plan.route.max_inter_node_bytes(
                &topo,
                &pay(&|e| e.vis_len as f64 * cfg.vis_bytes_per_unit),
            ) / 1e6,
        );
        inter[1].push(
            plan.audio.plan.route.max_inter_node_bytes(
                &topo,
                &pay(&|e| e.aud_len as f64 * cfg.aud_bytes_per_unit),
            ) / 1e6,
        );
        inter[2].push(
            plan.llm.route.max_inter_node_bytes(
                &topo,
                &pay(&|e| e.text_len as f64 * cfg.text_bytes_per_token),
            ) / 1e6,
        );
        oom |= sim.oom;
    }

    let mut exported = false;
    if let Some(dir) = archive_out {
        session.export_archive(dir)?;
        exported = true;
    }
    let archive = if archive_in.is_some() || archive_out.is_some() {
        let (loaded, cold_reason) = match &warm {
            Some(WarmStart::Warm { .. }) => (true, None),
            Some(WarmStart::Cold { reason }) => (false, Some(reason.clone())),
            None => (false, None),
        };
        Some(ArchiveRunInfo {
            loaded,
            cold_reason,
            warm_start_hit_rate: if steps == 0 {
                0.0
            } else {
                session.stats().step_cache_hits() as f64 / steps as f64
            },
            first_step_cache_hit,
            first_plan_id,
            exported,
        })
    } else {
        None
    };

    Ok(RunSummary {
        system,
        model_name: model.name,
        gpus,
        mini_batch,
        steps,
        mfu: mfu.mean(),
        tpt: tpt.mean(),
        step_secs: step_s.mean(),
        comm_secs: comm_s.mean(),
        peak_mem_gb: mem.max() / 1e9,
        oom,
        dispatcher_overhead_ms: disp_ms.mean(),
        // Provenance comes straight from the session instead of being
        // re-derived from plan sources in the loop above.
        plan_ms: session.stats().mean_plan_ms(),
        plan_overlapped_pct: overlap.mean(),
        plan_stats: session.plan_time_stats(),
        inter_node_mb: [inter[0].mean(), inter[1].mean(), inter[2].mean()],
        archive,
        cosched,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(system: SystemKind, gpus: usize, mb: usize) -> RunSummary {
        simulate_run(
            system,
            &MllmConfig::mllm_10b(),
            gpus,
            mb,
            3,
            42,
        )
    }

    #[test]
    fn orchmllm_beats_no_balance() {
        let orch = quick(SystemKind::OrchMllm, 32, 30);
        let none = quick(SystemKind::NoBalance, 32, 30);
        assert!(
            orch.mfu > 1.2 * none.mfu,
            "orch {} vs none {}",
            orch.mfu,
            none.mfu
        );
        assert!(orch.tpt > none.tpt);
    }

    #[test]
    fn mfu_in_plausible_range() {
        let orch = quick(SystemKind::OrchMllm, 32, 30);
        assert!(
            orch.mfu > 0.25 && orch.mfu < 0.55,
            "mfu {}",
            orch.mfu
        );
    }

    #[test]
    fn llm_only_sits_between() {
        let orch = quick(SystemKind::OrchMllm, 32, 30);
        let llm = quick(SystemKind::LlmOnly, 32, 30);
        let none = quick(SystemKind::NoBalance, 32, 30);
        assert!(llm.mfu < orch.mfu, "llm {} orch {}", llm.mfu, orch.mfu);
        assert!(llm.mfu > none.mfu, "llm {} none {}", llm.mfu, none.mfu);
    }

    #[test]
    fn allgather_raises_memory() {
        let a2a = quick(SystemKind::OrchMllm, 32, 30);
        let ag = quick(SystemKind::AllGatherComm, 32, 30);
        assert!(ag.peak_mem_gb > a2a.peak_mem_gb);
        assert!(ag.mfu <= a2a.mfu);
    }

    #[test]
    fn nodewise_reduces_inter_node_bytes() {
        let with = quick(SystemKind::OrchMllm, 32, 30);
        let without = quick(SystemKind::NoNodewise, 32, 30);
        let s_with: f64 = with.inter_node_mb.iter().sum();
        let s_without: f64 = without.inter_node_mb.iter().sum();
        assert!(
            s_with < s_without,
            "with {s_with} !< without {s_without}"
        );
    }

    #[test]
    fn composition_reduces_comm_seconds() {
        let with = quick(SystemKind::OrchMllm, 32, 30);
        let without = quick(SystemKind::NoComposition, 32, 30);
        assert!(with.comm_secs < without.comm_secs);
    }

    #[test]
    fn planning_overlaps_fully_at_simulated_scale() {
        let orch = quick(SystemKind::OrchMllm, 32, 30);
        // Plan time is measured and nonzero, yet fully hidden behind
        // the (seconds-scale) phase compute — the §6 claim.
        assert!(orch.plan_ms > 0.0, "plan time not measured");
        assert!(
            orch.plan_overlapped_pct > 99.0,
            "overlap {}%",
            orch.plan_overlapped_pct
        );
    }

    #[test]
    fn plan_time_percentiles_are_populated_and_ordered() {
        let orch = quick(SystemKind::OrchMllm, 32, 30);
        let ps = orch.plan_stats;
        assert!(ps.p50_ms > 0.0, "p50 not measured");
        assert!(ps.p95_ms >= ps.p50_ms);
        assert!(ps.p99_ms >= ps.p95_ms);
        // 3 steps × 3 phases were classified somewhere.
        assert!(ps.warm_rate >= 0.0 && ps.warm_rate <= 1.0);
        assert!(ps.cache_hit_rate >= 0.0 && ps.cache_hit_rate <= 1.0);
        // The first step can never be warm: with a single cold step and
        // random (non-recurring) batches, cold mean is measured.
        assert!(ps.cold_ms > 0.0, "cold step not classified");
    }

    #[test]
    fn balancer_override_resolves_through_registry() {
        let kk = simulate_run_named(
            SystemKind::OrchMllm,
            &MllmConfig::mllm_10b(),
            32,
            30,
            2,
            42,
            Some("kk"),
        );
        let none = simulate_run_named(
            SystemKind::OrchMllm,
            &MllmConfig::mllm_10b(),
            32,
            30,
            2,
            42,
            Some("none"),
        );
        assert!(
            kk.mfu > 1.1 * none.mfu,
            "kk {} vs none {}",
            kk.mfu,
            none.mfu
        );
    }

    /// Text+image-only config (two-modality regression shape).
    fn text_image_only() -> MllmConfig {
        use crate::model::config::{BlockStyle, SubmoduleConfig};
        MllmConfig {
            audio: SubmoduleConfig {
                layers: 0,
                hidden: 0,
                ffn_hidden: 0,
                style: BlockStyle::Encoder,
                conv_frontend: false,
            },
            ..MllmConfig::mllm_10b()
        }
    }

    #[test]
    fn phase_costs_opt_marks_absent_submodules() {
        let [vis, aud, llm] = phase_costs_opt(&text_image_only());
        assert!(vis.is_some() && llm.is_some());
        assert!(aud.is_none(), "absent audio must not price as α = 0");
        // All Table-1 models carry all three.
        for m in MllmConfig::all() {
            assert!(phase_costs_opt(&m).iter().all(Option::is_some));
        }
    }

    #[test]
    #[should_panic(expected = "use phase_costs_opt")]
    fn phase_costs_rejects_two_modality_models() {
        let _ = phase_costs(&text_image_only());
    }

    #[test]
    fn megatron_is_much_slower() {
        let orch = quick(SystemKind::OrchMllm, 32, 30);
        let mega = quick(SystemKind::Megatron, 32, 30);
        assert!(
            orch.mfu / mega.mfu > 2.0,
            "ratio {}",
            orch.mfu / mega.mfu
        );
    }
}
