//! Megatron-LM baseline model (the Fig. 8/9 comparator).
//!
//! The paper retrofits Megatron-LM's text-image workflow for three-
//! submodule MLLMs: encoders folded into the first pipeline stage(s),
//! PP sizes 2/4/10 and TP 8 across the three model sizes, and no batch
//! balancing of any kind. Its efficiency loss decomposes into factors
//! the literature (and the paper's §8.1 discussion) attributes it:
//!
//! * **pipeline bubble**: (p-1)/(m+p-1) idle fraction with m
//!   microbatches in flight;
//! * **model heterogeneity**: encoders cannot be tensor/pipeline-split
//!   like the LLM trunk, so stage loads are uneven — the pipeline runs
//!   at the speed of its slowest stage [DistTrain §2.3];
//! * **TP overhead**: per-layer all-reduces at TP=8 cost a fixed
//!   efficiency factor;
//! * **DP mini-batch imbalance**: identical to the no-balance system,
//!   priced from the sampled data per step.

use crate::balance::types::ExampleRef;
use crate::comm::topology::Topology;
use crate::data::synth::{DatasetConfig, Example, Generator};
use crate::model::config::MllmConfig;
use crate::model::flops::PhaseKind;
use crate::util::stats::Summary;

use super::engine::{phase_costs, phase_padded, RunSummary, SystemKind};
use super::gpu::GpuSpec;

/// Paper-configured PP size per model (TP universally 8).
pub fn paper_pp(model: &MllmConfig) -> usize {
    match model.name {
        "MLLM-10B" => 2,
        "MLLM-18B" => 4,
        _ => 10,
    }
}

pub const PAPER_TP: usize = 8;

/// Megatron microbatch size (sequences per microbatch): inputs inside a
/// microbatch are padded to the longest member, which is where the
/// framework pays for skipping rmpad-style packing.
const MICROBATCH: usize = 8;

/// Pipeline-stage load split: encoders live in the first stage (the
/// paper's retrofit); LLM layers are redistributed integer-wise to even
/// the stages out (the best Megatron can do without splitting encoder
/// modules). Returns mean/max stage balance in [0, 1].
fn stage_balance(model: &MllmConfig, pp: usize, batch: &[Example]) -> f64 {
    let costs = phase_costs(model);
    let mk = |phase: PhaseKind, f: fn(&Example) -> usize| -> f64 {
        let refs: Vec<ExampleRef> = batch
            .iter()
            .filter(|e| f(e) > 0)
            .enumerate()
            .map(|(id, e)| ExampleRef { id, len: f(e) })
            .collect();
        costs[match phase {
            PhaseKind::Vision => 0,
            PhaseKind::Audio => 1,
            PhaseKind::Llm => 2,
        }]
        .flops(&refs, phase_padded(phase))
    };
    let enc = mk(PhaseKind::Vision, |e| e.vis_len)
        + mk(PhaseKind::Audio, |e| e.aud_len);
    let llm = mk(PhaseKind::Llm, |e| e.llm_len());
    if pp == 1 {
        return 1.0;
    }
    let layers = model.llm.layers as f64;
    let per_layer = llm / layers;
    let mut best = 0.0f64;
    // Choose how many LLM layers share stage 0 with the encoders.
    for k in 0..model.llm.layers {
        let s0 = enc + k as f64 * per_layer;
        let rest = (layers - k as f64) * per_layer / (pp as f64 - 1.0);
        let max = s0.max(rest);
        let mean = (enc + llm) / pp as f64;
        best = best.max(mean / max);
    }
    best.min(1.0)
}

/// Simulate a Megatron-LM run with the paper's PP/TP settings.
#[allow(clippy::too_many_arguments)]
pub fn simulate_megatron(
    model: &MllmConfig,
    gpu: &GpuSpec,
    gpus: usize,
    mini_batch: usize,
    steps: usize,
    seed: u64,
    data_cfg: &DatasetConfig,
) -> RunSummary {
    let topo = Topology::h100(gpus);
    let pp = paper_pp(model);
    let tp = PAPER_TP;
    let dp = (gpus / (pp * tp)).max(1);
    let mut generator = Generator::new(*data_cfg, seed);
    let costs = phase_costs(model);

    // Match OrchMLLM's *global* batch: its DP width is `gpus`, each
    // sampling `mini_batch` examples, so one Megatron replica (pp*tp
    // GPUs) owns mini_batch*pp*tp examples per step.
    let replica_batch = mini_batch * pp * tp;
    // Microbatches in flight: sequence-level micro-batching.
    let m = replica_batch.max(1) as f64;
    let bubble_eff = m / (m + pp as f64 - 1.0);
    // TP=8 all-reduce tax on per-layer matmuls (communication not
    // hideable at this width on IB-connected nodes).
    let tp_eff = 0.82;

    let mut mfu_s = Summary::new();
    let mut tpt_s = Summary::new();
    let mut step_s = Summary::new();
    let mut stage_s = Summary::new();

    for _ in 0..steps {
        // dp replicas each sample a replica batch; imbalance priced like
        // the no-balance system.
        let batches: Vec<Vec<Example>> =
            (0..dp).map(|_| generator.batch(replica_batch)).collect();

        let mut eff_flops = 0.0f64;
        let mut slowest = 0.0f64;
        let mut llm_tokens = 0.0f64;
        let mut stage_eff = 1.0f64;
        for b in &batches {
            let mut total = 0.0;
            for (pi, phase) in PhaseKind::ALL.iter().enumerate() {
                let f: fn(&Example) -> usize = match phase {
                    PhaseKind::Vision => |e| e.vis_len,
                    PhaseKind::Audio => |e| e.aud_len,
                    PhaseKind::Llm => |e| e.llm_len(),
                };
                let refs: Vec<ExampleRef> = b
                    .iter()
                    .filter(|e| f(e) > 0)
                    .enumerate()
                    .map(|(id, e)| ExampleRef { id, len: f(e) })
                    .collect();
                // Megatron pads inside each microbatch (no rmpad
                // packing in the retrofit): computed FLOPs use the
                // padded cost per MICROBATCH chunk; effective FLOPs use
                // true lengths.
                for chunk in refs.chunks(MICROBATCH) {
                    total += costs[pi].flops(chunk, true);
                }
                eff_flops += costs[pi].effective_flops(&refs);
            }
            slowest = slowest.max(total);
            llm_tokens +=
                b.iter().map(|e| e.llm_len() as f64).sum::<f64>();
            stage_eff = stage_eff.min(stage_balance(model, pp, b));
        }
        stage_s.push(stage_eff);

        // One DP replica owns pp*tp GPUs; its compute throughput is the
        // product of GPUs and the efficiency factors.
        let replica_flops = gpu.peak_flops
            * gpu.kernel_eff
            * (pp * tp) as f64
            * bubble_eff
            * tp_eff
            * stage_eff;
        let compute = slowest / replica_flops;
        // DP gradient sync, mostly overlapped with backward (same
        // overlap assumption as the FSDP path in engine.rs).
        let grad_sync = 0.15
            * 3.0
            * crate::comm::costmodel::allreduce_cost(
                &topo,
                2.0 * model.total_params(),
            )
            .seconds;
        let step = compute + grad_sync + gpu.step_overhead;
        step_s.push(step);
        mfu_s.push(eff_flops / (step * gpu.peak_flops * gpus as f64));
        tpt_s.push(llm_tokens / (step * gpus as f64));
    }

    RunSummary {
        system: SystemKind::Megatron,
        model_name: model.name,
        gpus,
        mini_batch,
        steps,
        mfu: mfu_s.mean(),
        tpt: tpt_s.mean(),
        step_secs: step_s.mean(),
        comm_secs: 0.0,
        peak_mem_gb: 0.0, // not modelled for the baseline
        oom: false,
        dispatcher_overhead_ms: 0.0,
        plan_ms: 0.0,
        plan_overlapped_pct: 100.0,
        plan_stats: crate::sim::engine::PlanTimeStats::default(),
        inter_node_mb: [0.0; 3],
        archive: None,
        cosched: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pp_sizes_match_paper() {
        assert_eq!(paper_pp(&MllmConfig::mllm_10b()), 2);
        assert_eq!(paper_pp(&MllmConfig::mllm_18b()), 4);
        assert_eq!(paper_pp(&MllmConfig::mllm_84b()), 10);
    }

    #[test]
    fn stage_imbalance_below_one() {
        let model = MllmConfig::mllm_10b();
        let mut g = Generator::new(DatasetConfig::default(), 3);
        let batch = g.batch(32);
        let s = stage_balance(&model, 2, &batch);
        assert!(s > 0.1 && s < 1.0, "stage balance {s}");
    }

    #[test]
    fn megatron_mfu_is_low() {
        let model = MllmConfig::mllm_10b();
        let r = simulate_megatron(
            &model,
            &GpuSpec::h100(),
            64,
            32,
            3,
            9,
            &DatasetConfig::default(),
        );
        assert!(r.mfu > 0.02 && r.mfu < 0.25, "mfu {}", r.mfu);
    }

    /// Pinned Fig.-8-shaped scenario on a heavy-tail data profile: the
    /// Megatron baseline's step time must exceed the orchestrated
    /// system's at the same global batch (64 GPUs × 8 examples each).
    /// All-av-dialogue data is the longest-tailed mixture the generator
    /// produces — per-microbatch padding and encoder/LLM stage imbalance
    /// hurt the baseline most there.
    #[test]
    fn heavy_tail_megatron_step_exceeds_orchestrated() {
        use crate::data::synth::TaskMix;
        use crate::orchestrator::global::OrchestratorConfig;
        use crate::orchestrator::pipeline::PipelineConfig;
        use crate::orchestrator::session::{PlanOptions, PlanSession};
        use crate::sim::engine::simulate_step;

        let model = MllmConfig::mllm_10b();
        let gpu = GpuSpec::h100();
        let (gpus, mb, steps, seed) = (64usize, 8usize, 3usize, 9u64);
        let data_cfg = DatasetConfig {
            mix: TaskMix {
                asr: 0.0,
                spoken_qa: 0.0,
                caption: 0.0,
                vqa: 0.0,
                text_only: 0.0,
                av_dialogue: 1.0,
            },
            ..DatasetConfig::default()
        };

        let mega = simulate_megatron(
            &model, &gpu, gpus, mb, steps, seed, &data_cfg,
        );

        // The orchestrated side plans the *same* heavy-tail stream:
        // same data config, same seed, same global batch per step.
        let topo = Topology::h100(gpus);
        let cfg =
            OrchestratorConfig::orchmllm(model.llm.hidden as f64 * 2.0);
        let mut session =
            PlanSession::new(cfg, PipelineConfig::default(), topo);
        let mut generator = Generator::new(data_cfg, seed);
        let mut orch_step = 0.0f64;
        for _ in 0..steps {
            let minibatches: Vec<Vec<Example>> =
                (0..gpus).map(|_| generator.batch(mb)).collect();
            let plan = session.plan(&minibatches, PlanOptions::auto());
            let sim = simulate_step(&model, &topo, &gpu, &plan);
            orch_step += sim.step_secs / steps as f64;
        }

        assert!(
            mega.step_secs > orch_step,
            "megatron {} s/step !> orchestrated {} s/step",
            mega.step_secs,
            orch_step
        );
    }
}
