//! GPU device model.

/// One accelerator's capabilities.
#[derive(Clone, Copy, Debug)]
pub struct GpuSpec {
    /// Peak dense bf16 FLOP/s.
    pub peak_flops: f64,
    /// Device memory bytes.
    pub mem_bytes: f64,
    /// Fraction of peak achievable by well-shaped transformer kernels
    /// (flash attention + large GEMMs) — the single-GPU ceiling MFU.
    pub kernel_eff: f64,
    /// Fixed per-step overhead (optimizer step, host sync, launch
    /// tails), seconds.
    pub step_overhead: f64,
    /// Memory headroom fraction before the allocator OOMs.
    pub usable_mem_frac: f64,
}

impl GpuSpec {
    /// NVIDIA H100 SXM (the paper's testbed).
    pub fn h100() -> GpuSpec {
        GpuSpec {
            peak_flops: 989e12,
            mem_bytes: 80e9,
            // State-of-the-art LLM pretraining lands at 45–55% MFU on
            // H100 — the paper calls its 41.6% "approaching the
            // state-of-the-art efficiency of LLM training".
            kernel_eff: 0.52,
            step_overhead: 15e-3,
            usable_mem_frac: 0.94,
        }
    }

    /// NVIDIA A100 80GB SXM: the previous-generation part, ~1/3 the
    /// dense bf16 peak at the same memory capacity. Mature kernels
    /// reach a slightly higher fraction of the (lower) peak, and the
    /// fixed per-step overhead weighs a little heavier against slower
    /// compute.
    pub fn a100() -> GpuSpec {
        GpuSpec {
            peak_flops: 312e12,
            mem_bytes: 80e9,
            kernel_eff: 0.55,
            step_overhead: 15e-3,
            usable_mem_frac: 0.94,
        }
    }

    /// Resolve a `--gpu` CLI name. `None` for unknown parts — callers
    /// render [`GpuSpec::NAMES`] in their error.
    pub fn by_name(name: &str) -> Option<GpuSpec> {
        match name.to_ascii_lowercase().as_str() {
            "h100" => Some(Self::h100()),
            "a100" => Some(Self::a100()),
            _ => None,
        }
    }

    /// The selectable part names, for help text and error messages.
    pub const NAMES: [&'static str; 2] = ["h100", "a100"];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h100_constants_sane() {
        let g = GpuSpec::h100();
        assert!(g.peak_flops > 5e14);
        assert_eq!(g.mem_bytes, 80e9);
        assert!(g.kernel_eff > 0.3 && g.kernel_eff < 0.7);
    }

    #[test]
    fn a100_is_a_slower_part_with_equal_memory() {
        let a = GpuSpec::a100();
        let h = GpuSpec::h100();
        assert!(a.peak_flops < h.peak_flops / 2.0);
        assert_eq!(a.mem_bytes, h.mem_bytes);
        assert!(a.kernel_eff > 0.3 && a.kernel_eff < 0.7);
    }

    #[test]
    fn by_name_resolves_every_listed_part() {
        for name in GpuSpec::NAMES {
            assert!(GpuSpec::by_name(name).is_some(), "{name}");
        }
        assert!(GpuSpec::by_name("H100").is_some(), "case-insensitive");
        assert!(GpuSpec::by_name("tpu-v5").is_none());
        assert!(
            GpuSpec::by_name("a100").unwrap().peak_flops
                < GpuSpec::by_name("h100").unwrap().peak_flops
        );
    }
}
