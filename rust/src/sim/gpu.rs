//! GPU device model.

/// One accelerator's capabilities.
#[derive(Clone, Copy, Debug)]
pub struct GpuSpec {
    /// Peak dense bf16 FLOP/s.
    pub peak_flops: f64,
    /// Device memory bytes.
    pub mem_bytes: f64,
    /// Fraction of peak achievable by well-shaped transformer kernels
    /// (flash attention + large GEMMs) — the single-GPU ceiling MFU.
    pub kernel_eff: f64,
    /// Fixed per-step overhead (optimizer step, host sync, launch
    /// tails), seconds.
    pub step_overhead: f64,
    /// Memory headroom fraction before the allocator OOMs.
    pub usable_mem_frac: f64,
}

impl GpuSpec {
    /// NVIDIA H100 SXM (the paper's testbed).
    pub fn h100() -> GpuSpec {
        GpuSpec {
            peak_flops: 989e12,
            mem_bytes: 80e9,
            // State-of-the-art LLM pretraining lands at 45–55% MFU on
            // H100 — the paper calls its 41.6% "approaching the
            // state-of-the-art efficiency of LLM training".
            kernel_eff: 0.52,
            step_overhead: 15e-3,
            usable_mem_frac: 0.94,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h100_constants_sane() {
        let g = GpuSpec::h100();
        assert!(g.peak_flops > 5e14);
        assert_eq!(g.mem_bytes, 80e9);
        assert!(g.kernel_eff > 0.3 && g.kernel_eff < 0.7);
    }
}
