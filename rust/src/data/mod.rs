//! Multimodal training data: the synthetic task-mixture generator that
//! reproduces Modality Composition Incoherence (paper §3.1 / Fig. 3),
//! the incoherence statistics, and the prefetching dataloader whose
//! prefetch slot hosts the dispatchers' computation (paper §6,
//! "Computation overhead overlapping").

pub mod incoherence;
pub mod loader;
pub mod synth;

pub use synth::{DatasetConfig, Example, Task, TaskMix};
