//! Synthetic multimodal task-mixture generator.
//!
//! The paper's production datasets are proprietary; what matters to
//! every experiment is their *distributional* structure (§3.1):
//!
//! * **ASR** — paired audio+text, lengths strongly positively
//!   correlated (long speech → long transcript);
//! * **Spoken QA** — audio-heavy, text length decorrelated (a long
//!   question may get a "yes");
//! * **Caption** — image-only input, short text, no audio;
//! * **VQA** — image + medium text, no audio;
//! * **Text-only** — instruction data with no metadata at all;
//! * **AV dialogue** — both modalities present (omni-model data).
//!
//! Mixing these tasks yields per-modality sequence-ratio distributions
//! with the heavy spread of Fig. 3 — the generator's acceptance test.

use crate::util::rng::Pcg64;

/// Task types in the instruction-tuning mixture.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Task {
    Asr,
    SpokenQa,
    Caption,
    Vqa,
    TextOnly,
    AvDialogue,
}

impl Task {
    pub const ALL: [Task; 6] = [
        Task::Asr,
        Task::SpokenQa,
        Task::Caption,
        Task::Vqa,
        Task::TextOnly,
        Task::AvDialogue,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Task::Asr => "asr",
            Task::SpokenQa => "spoken-qa",
            Task::Caption => "caption",
            Task::Vqa => "vqa",
            Task::TextOnly => "text-only",
            Task::AvDialogue => "av-dialogue",
        }
    }
}

/// One training example's per-modality metadata lengths.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Example {
    pub id: usize,
    pub task: Task,
    /// Vision metadata length (image patches; 0 when absent).
    pub vis_len: usize,
    /// Audio metadata length (mel frames; 0 when absent).
    pub aud_len: usize,
    /// Text token count.
    pub text_len: usize,
    /// Encoder-output subsequence lengths after downsampling.
    pub vis_tokens: usize,
    pub aud_tokens: usize,
}

impl Example {
    /// Interleaved LLM-phase sequence length (text + subsequences).
    pub fn llm_len(&self) -> usize {
        self.text_len + self.vis_tokens + self.aud_tokens
    }

    /// Proportion of the LLM sequence contributed by vision (Fig. 3 x).
    pub fn vis_ratio(&self) -> f64 {
        self.vis_tokens as f64 / self.llm_len().max(1) as f64
    }

    pub fn aud_ratio(&self) -> f64 {
        self.aud_tokens as f64 / self.llm_len().max(1) as f64
    }
}

/// Task mixture weights (normalized on use).
#[derive(Clone, Copy, Debug)]
pub struct TaskMix {
    pub asr: f64,
    pub spoken_qa: f64,
    pub caption: f64,
    pub vqa: f64,
    pub text_only: f64,
    pub av_dialogue: f64,
}

impl Default for TaskMix {
    /// A plausible omni instruction-tuning mixture.
    fn default() -> Self {
        TaskMix {
            asr: 0.2,
            spoken_qa: 0.15,
            caption: 0.2,
            vqa: 0.2,
            text_only: 0.15,
            av_dialogue: 0.1,
        }
    }
}

impl TaskMix {
    fn weights(&self) -> [f64; 6] {
        [
            self.asr,
            self.spoken_qa,
            self.caption,
            self.vqa,
            self.text_only,
            self.av_dialogue,
        ]
    }
}

/// Length-scale parameters for the generator.
#[derive(Clone, Copy, Debug)]
pub struct DatasetConfig {
    pub mix: TaskMix,
    /// Vision downsample rate (metadata patches per LLM token).
    pub vis_downsample: usize,
    /// Audio downsample rate.
    pub aud_downsample: usize,
    /// Hard caps (paper: images above the resolution cap are resized;
    /// sequences range "10 .. 40k" in production).
    pub max_vis: usize,
    pub max_aud: usize,
    pub max_text: usize,
    /// Global length scale multiplier (1.0 = production-like; tests use
    /// smaller for speed).
    pub scale: f64,
}

impl Default for DatasetConfig {
    fn default() -> Self {
        DatasetConfig {
            mix: TaskMix::default(),
            vis_downsample: 4,
            aud_downsample: 2,
            max_vis: 4096,
            max_aud: 3000,
            max_text: 4096,
            scale: 1.0,
        }
    }
}

impl DatasetConfig {
    /// A tiny-range config matched to the `test`/`e2e-small` AOT buckets
    /// (per-example lengths stay below the compiled buffer shapes).
    pub fn tiny(vis_downsample: usize, aud_downsample: usize)
        -> DatasetConfig {
        DatasetConfig {
            mix: TaskMix::default(),
            vis_downsample,
            aud_downsample,
            max_vis: 16,
            max_aud: 16,
            max_text: 24,
            scale: 0.02,
        }
    }
}

/// Deterministic streaming generator.
pub struct Generator {
    cfg: DatasetConfig,
    rng: Pcg64,
    next_id: usize,
}

impl Generator {
    pub fn new(cfg: DatasetConfig, seed: u64) -> Generator {
        Generator { cfg, rng: Pcg64::new(seed), next_id: 0 }
    }

    fn lognorm(&mut self, median: f64, sigma: f64) -> f64 {
        self.rng.lognormal((median * self.cfg.scale).max(1.0).ln(), sigma)
    }

    /// Round a metadata length up so it divides the downsample rate
    /// (mirrors L2's grouping connector).
    fn round_up(len: usize, r: usize) -> usize {
        len.div_ceil(r) * r
    }

    pub fn sample(&mut self) -> Example {
        let cfg = self.cfg;
        let task = Task::ALL[self.rng.weighted(&cfg.mix.weights())];
        let (vis, aud, text) = match task {
            Task::Asr => {
                // Audio length drives text length (strong correlation):
                // ~ 16k samples/s, ~2.5 tokens/s of speech.
                let a = self.lognorm(800.0, 0.7);
                let t = (a * 0.25 * (0.8 + 0.4 * self.rng.f64())).max(2.0);
                (0.0, a, t)
            }
            Task::SpokenQa => {
                // Long question, decorrelated (often tiny) answer.
                let a = self.lognorm(1200.0, 0.6);
                let t = self.lognorm(30.0, 1.2);
                (0.0, a, t)
            }
            Task::Caption => {
                let v = self.lognorm(1024.0, 0.5);
                let t = self.lognorm(40.0, 0.6);
                (v, 0.0, t)
            }
            Task::Vqa => {
                let v = self.lognorm(1024.0, 0.5);
                let t = self.lognorm(120.0, 0.8);
                (v, 0.0, t)
            }
            Task::TextOnly => {
                let t = self.lognorm(400.0, 1.0);
                (0.0, 0.0, t)
            }
            Task::AvDialogue => {
                let v = self.lognorm(768.0, 0.5);
                let a = self.lognorm(600.0, 0.6);
                let t = self.lognorm(150.0, 0.7);
                (v, a, t)
            }
        };
        let vis_len = if vis > 0.0 {
            Self::round_up(
                (vis.round() as usize).clamp(1, cfg.max_vis),
                cfg.vis_downsample,
            )
        } else {
            0
        };
        let aud_len = if aud > 0.0 {
            Self::round_up(
                (aud.round() as usize).clamp(1, cfg.max_aud),
                cfg.aud_downsample,
            )
        } else {
            0
        };
        let text_len = (text.round() as usize).clamp(1, cfg.max_text);
        let e = Example {
            id: self.next_id,
            task,
            vis_len,
            aud_len,
            text_len,
            vis_tokens: vis_len / cfg.vis_downsample,
            aud_tokens: aud_len / cfg.aud_downsample,
        };
        self.next_id += 1;
        e
    }

    /// Sample a batch of examples.
    pub fn batch(&mut self, n: usize) -> Vec<Example> {
        (0..n).map(|_| self.sample()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    fn gen(n: usize) -> Vec<Example> {
        Generator::new(DatasetConfig::default(), 7).batch(n)
    }

    #[test]
    fn all_tasks_appear() {
        let ex = gen(5000);
        for t in Task::ALL {
            assert!(
                ex.iter().filter(|e| e.task == t).count() > 100,
                "task {t:?} undersampled"
            );
        }
    }

    #[test]
    fn task_structure_holds() {
        for e in gen(3000) {
            match e.task {
                Task::Asr | Task::SpokenQa => {
                    assert_eq!(e.vis_len, 0);
                    assert!(e.aud_len > 0);
                }
                Task::Caption | Task::Vqa => {
                    assert!(e.vis_len > 0);
                    assert_eq!(e.aud_len, 0);
                }
                Task::TextOnly => {
                    assert_eq!(e.vis_len + e.aud_len, 0);
                }
                Task::AvDialogue => {
                    assert!(e.vis_len > 0 && e.aud_len > 0);
                }
            }
            assert!(e.text_len > 0);
            assert_eq!(e.vis_len % 4, 0);
            assert_eq!(e.aud_len % 2, 0);
            assert_eq!(e.llm_len(), e.text_len + e.vis_tokens + e.aud_tokens);
        }
    }

    #[test]
    fn asr_lengths_are_correlated() {
        let ex: Vec<Example> =
            gen(20_000).into_iter().filter(|e| e.task == Task::Asr).collect();
        let xs: Vec<f64> = ex.iter().map(|e| e.aud_len as f64).collect();
        let ys: Vec<f64> = ex.iter().map(|e| e.text_len as f64).collect();
        assert!(pearson(&xs, &ys) > 0.7, "r = {}", pearson(&xs, &ys));
    }

    #[test]
    fn spoken_qa_lengths_are_not() {
        let ex: Vec<Example> = gen(20_000)
            .into_iter()
            .filter(|e| e.task == Task::SpokenQa)
            .collect();
        let xs: Vec<f64> = ex.iter().map(|e| e.aud_len as f64).collect();
        let ys: Vec<f64> = ex.iter().map(|e| e.text_len as f64).collect();
        assert!(pearson(&xs, &ys).abs() < 0.2, "r = {}", pearson(&xs, &ys));
    }

    #[test]
    fn modality_ratios_have_fig3_spread() {
        // The defining property: per-modality composition ratios bear
        // "substantial variance" — mass at 0, mass near 1, wide middle.
        let ex = gen(20_000);
        let vis = Summary::from_slice(
            &ex.iter().map(|e| e.vis_ratio()).collect::<Vec<_>>(),
        );
        let aud = Summary::from_slice(
            &ex.iter().map(|e| e.aud_ratio()).collect::<Vec<_>>(),
        );
        assert!(vis.std() > 0.25, "vis ratio std {}", vis.std());
        assert!(aud.std() > 0.25, "aud ratio std {}", aud.std());
        // Both extremes populated.
        assert!(ex.iter().any(|e| e.vis_ratio() == 0.0));
        assert!(ex.iter().any(|e| e.vis_ratio() > 0.8));
        assert!(ex.iter().any(|e| e.aud_ratio() == 0.0));
        assert!(ex.iter().any(|e| e.aud_ratio() > 0.8));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Generator::new(DatasetConfig::default(), 42).batch(50);
        let b = Generator::new(DatasetConfig::default(), 42).batch(50);
        assert_eq!(a, b);
    }

    #[test]
    fn tiny_config_respects_caps() {
        let cfg = DatasetConfig::tiny(2, 2);
        let ex = Generator::new(cfg, 1).batch(2000);
        for e in &ex {
            assert!(e.vis_len <= 16 && e.aud_len <= 16 && e.text_len <= 24);
        }
    }

    fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
        let n = xs.len() as f64;
        let mx = xs.iter().sum::<f64>() / n;
        let my = ys.iter().sum::<f64>() / n;
        let cov: f64 =
            xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
        let sx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum::<f64>();
        let sy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum::<f64>();
        cov / (sx * sy).sqrt()
    }
}
