//! Modality Composition Incoherence statistics (paper §3.1, Fig. 3).
//!
//! Quantifies, over a sample of the dataset, the distribution of each
//! modality's share of the interleaved LLM sequence. The Fig.-3 claim is
//! that these ratios "bear substantial variance" — which is what makes
//! pre-balancing a multi-objective problem.

use super::synth::Example;
use crate::util::stats::{sparkline, Summary};

/// Ratio distributions for one modality.
#[derive(Clone, Debug)]
pub struct RatioStats {
    pub modality: &'static str,
    pub summary: Summary,
    /// Fraction of examples where the modality is absent entirely.
    pub absent_frac: f64,
    /// Normalized histogram over [0, 1] (Fig.-3 panel).
    pub histogram: Vec<f64>,
}

impl RatioStats {
    fn build(modality: &'static str, ratios: &[f64], bins: usize)
        -> RatioStats {
        let absent =
            ratios.iter().filter(|&&r| r == 0.0).count() as f64
                / ratios.len().max(1) as f64;
        let s = Summary::from_slice(ratios);
        let histogram = s.histogram(0.0, 1.0, bins);
        RatioStats { modality, summary: s, absent_frac: absent, histogram }
    }

    /// Terminal rendering of one Fig.-3 panel.
    pub fn render(&self) -> String {
        format!(
            "{:<8} mean={:.3} std={:.3} absent={:.1}%  {}",
            self.modality,
            self.summary.mean(),
            self.summary.std(),
            self.absent_frac * 100.0,
            sparkline(&self.histogram)
        )
    }
}

/// The full Fig.-3 analysis over a dataset sample.
#[derive(Clone, Debug)]
pub struct IncoherenceReport {
    pub vision: RatioStats,
    pub audio: RatioStats,
    pub n: usize,
}

impl IncoherenceReport {
    pub fn from_examples(examples: &[Example], bins: usize)
        -> IncoherenceReport {
        let vis: Vec<f64> = examples.iter().map(|e| e.vis_ratio()).collect();
        let aud: Vec<f64> = examples.iter().map(|e| e.aud_ratio()).collect();
        IncoherenceReport {
            vision: RatioStats::build("vision", &vis, bins),
            audio: RatioStats::build("audio", &aud, bins),
            n: examples.len(),
        }
    }

    /// The paper's qualitative claim, as a predicate: both modalities'
    /// ratio distributions have wide spread.
    pub fn is_incoherent(&self) -> bool {
        self.vision.summary.std() > 0.2 && self.audio.summary.std() > 0.2
    }

    pub fn render(&self) -> String {
        format!(
            "Modality Composition Incoherence (n={}):\n  {}\n  {}",
            self.n,
            self.vision.render(),
            self.audio.render()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{DatasetConfig, Generator};

    #[test]
    fn report_flags_mixture_as_incoherent() {
        let ex = Generator::new(DatasetConfig::default(), 3).batch(10_000);
        let rep = IncoherenceReport::from_examples(&ex, 20);
        assert!(rep.is_incoherent());
        assert_eq!(rep.n, 10_000);
        assert!((rep.vision.histogram.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn single_task_dataset_is_coherent() {
        // ASR-only data: vision ratio constant 0; audio ratio narrow.
        let mut cfg = DatasetConfig::default();
        cfg.mix.asr = 1.0;
        cfg.mix.spoken_qa = 0.0;
        cfg.mix.caption = 0.0;
        cfg.mix.vqa = 0.0;
        cfg.mix.text_only = 0.0;
        cfg.mix.av_dialogue = 0.0;
        let ex = Generator::new(cfg, 4).batch(5000);
        let rep = IncoherenceReport::from_examples(&ex, 20);
        assert!(!rep.is_incoherent(), "{}", rep.render());
        assert_eq!(rep.vision.absent_frac, 1.0);
    }

    #[test]
    fn render_contains_both_modalities() {
        let ex = Generator::new(DatasetConfig::default(), 5).batch(500);
        let rep = IncoherenceReport::from_examples(&ex, 10);
        let s = rep.render();
        assert!(s.contains("vision") && s.contains("audio"));
    }
}
