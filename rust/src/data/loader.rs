//! Prefetching dataloader with overlapped dispatcher computation.
//!
//! Paper §6 ("Computation overhead overlapping"): the Post-Balancing /
//! Node-wise algorithms need only the sequence *lengths* of the sampled
//! mini-batches, which are known at sampling time — so their computation
//! is folded into the dataloader's prefetch thread and runs concurrently
//! with the previous step's forward pass. Only the All-to-All
//! *communication* remains on the critical path.

use std::sync::mpsc;
use std::thread::JoinHandle;

use super::synth::{DatasetConfig, Example, Generator};

/// One prefetched step: the sampled per-instance mini-batches, the
/// planner's output (dispatch plans), and how long planning took —
/// time that is *off* the critical path.
pub struct PrefetchedStep<P> {
    pub minibatches: Vec<Vec<Example>>,
    pub plan: P,
    pub plan_nanos: u128,
}

/// Background sampler + planner.
pub struct Prefetcher<P: Send + 'static> {
    rx: Option<mpsc::Receiver<PrefetchedStep<P>>>,
    handle: Option<JoinHandle<()>>,
}

impl<P: Send + 'static> Prefetcher<P> {
    /// Start prefetching: `d` instances × `batch_size` examples per
    /// step, planner executed in the prefetch thread. `depth` bounds the
    /// number of planned-but-unconsumed steps. The planner is `FnMut`
    /// so it can own reusable state (e.g. a
    /// [`crate::orchestrator::StepScratch`]) across steps.
    #[allow(clippy::too_many_arguments)]
    pub fn new<F>(
        cfg: DatasetConfig,
        seed: u64,
        d: usize,
        batch_size: usize,
        steps: usize,
        depth: usize,
        mut planner: F,
    ) -> Prefetcher<P>
    where
        F: FnMut(&[Vec<Example>]) -> P + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel(depth.max(1));
        let handle = std::thread::spawn(move || {
            let mut generator = Generator::new(cfg, seed);
            for _ in 0..steps {
                let minibatches: Vec<Vec<Example>> =
                    (0..d).map(|_| generator.batch(batch_size)).collect();
                let t0 = std::time::Instant::now();
                let plan = planner(&minibatches);
                let plan_nanos = t0.elapsed().as_nanos();
                if tx
                    .send(PrefetchedStep { minibatches, plan, plan_nanos })
                    .is_err()
                {
                    return; // consumer dropped
                }
            }
        });
        Prefetcher { rx: Some(rx), handle: Some(handle) }
    }

    /// Blocking fetch of the next planned step; `None` when exhausted.
    pub fn next(&self) -> Option<PrefetchedStep<P>> {
        self.rx.as_ref().and_then(|rx| rx.recv().ok())
    }
}

impl<P: Send + 'static> Drop for Prefetcher<P> {
    fn drop(&mut self) {
        // Close the channel first so a producer blocked in send() gets a
        // SendError and exits, *then* join it.
        drop(self.rx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn yields_planned_steps_in_order() {
        let pf = Prefetcher::new(
            DatasetConfig::tiny(2, 2),
            9,
            4,
            8,
            5,
            2,
            |mbs| mbs.iter().map(|b| b.len()).sum::<usize>(),
        );
        let mut n = 0;
        while let Some(step) = pf.next() {
            assert_eq!(step.minibatches.len(), 4);
            assert_eq!(step.plan, 32);
            n += 1;
        }
        assert_eq!(n, 5);
    }

    #[test]
    fn planner_time_is_recorded() {
        let pf = Prefetcher::new(
            DatasetConfig::tiny(2, 2),
            10,
            2,
            4,
            1,
            1,
            |_| std::thread::sleep(std::time::Duration::from_millis(2)),
        );
        let step = pf.next().unwrap();
        assert!(step.plan_nanos >= 2_000_000);
    }

    #[test]
    fn early_drop_does_not_hang() {
        let pf = Prefetcher::new(
            DatasetConfig::tiny(2, 2),
            11,
            2,
            4,
            100,
            1,
            |_| (),
        );
        let _ = pf.next();
        drop(pf); // must join cleanly without consuming all 100
    }

    #[test]
    fn sampling_is_deterministic_given_seed() {
        let get = || {
            let pf = Prefetcher::new(
                DatasetConfig::tiny(2, 2),
                42,
                2,
                4,
                1,
                1,
                |_| (),
            );
            pf.next().unwrap().minibatches
        };
        assert_eq!(get(), get());
    }
}
